//! The paper's three production workloads (Table 3), calibrated for the
//! discrete-event simulator.
//!
//! Hardware constants model one *instance* (the TP/EP group serving one
//! model replica) on H800s: decode is memory-bound (weight stream + KV
//! read), prefill/verification are compute-bound. The absolute constants
//! are estimates from public H800 specs (3.35 TB/s HBM, ~700 dense
//! bf16 TFLOP/s effective per GPU with MFU ~0.4); the *experiments* only
//! depend on their ratios, which drive who-wins/by-how-much shapes.

use super::{HardwareConfig, WorkloadConfig};
use crate::sim::clock::SimTime;

/// The paper's three evaluation workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskPreset {
    /// Moonlight (16B MoE, math reasoning): 32 GPUs, 1 per instance.
    Moonlight,
    /// Qwen2-VL-72B (dense, vision-language): 128 GPUs, TP8.
    Qwen2Vl72b,
    /// Kimi-K2 (1T MoE): 256 GPUs, DP32+EP32 (32 GPUs per instance).
    KimiK2,
}

pub const ALL_PRESETS: [TaskPreset; 3] =
    [TaskPreset::Moonlight, TaskPreset::Qwen2Vl72b, TaskPreset::KimiK2];

const GB: u64 = 1 << 30;
const TB_S: f64 = 1e12;
const TFLOP_S: f64 = 1e12;

impl TaskPreset {
    pub fn name(&self) -> &'static str {
        match self {
            TaskPreset::Moonlight => "moonlight",
            TaskPreset::Qwen2Vl72b => "qwen2-vl-72b",
            TaskPreset::KimiK2 => "kimi-k2",
        }
    }

    pub fn from_name(s: &str) -> Option<TaskPreset> {
        match s.to_ascii_lowercase().as_str() {
            "moonlight" => Some(TaskPreset::Moonlight),
            "qwen2-vl-72b" | "qwen" | "qwen2vl" => Some(TaskPreset::Qwen2Vl72b),
            "kimi-k2" | "kimi" | "k2" => Some(TaskPreset::KimiK2),
            _ => None,
        }
    }

    pub fn workload(&self) -> WorkloadConfig {
        match self {
            // ---------------------------------------------------------
            // Moonlight: 16B-A3B MoE (MLA KV ≈ 31 KB/token), 1 GPU per
            // instance. Memory-constrained: 80 GB − 32 GB weights −
            // ~8 GB activations ⇒ ~1.25M KV tokens. Long math CoT:
            // avg 22386, max 65536, heavy tail.
            // ---------------------------------------------------------
            TaskPreset::Moonlight => WorkloadConfig {
                name: "moonlight",
                n_instances: 32,
                gpus_per_instance: 1,
                reqs_per_iter: 3200,
                group_size: 8,
                temperature: 1.0,
                max_gen_len: 65536,
                avg_gen_len: 22386,
                sigma_between: 1.05,
                sigma_within: 0.28,
                avg_prompt_len: 1024,
                sigma_prompt: 0.5,
                sd_richness: 0.72,
                hw: HardwareConfig {
                    kv_capacity_tokens: 1_250_000,
                    kv_bytes_per_token: 31 * 1024,
                    step_overhead: SimTime::from_micros(1500),
                    // 32 GB weights / 3.35 TB/s, MoE activates ~20%:
                    // effective streamed bytes ≈ 8 GB ⇒ ~2.6 ms... but
                    // expert routing reads most experts at batch ≥ 64;
                    // use 24 GB effective ⇒ 7.5 ms.
                    weight_read_time: SimTime::from_micros(7500),
                    hbm_bw: 3.35 * TB_S,
                    // 700 dense TFLOPs x MFU 0.4 (MoE dispatch overhead).
                    flops: 280.0 * TFLOP_S,
                    // 2 x 3B active params.
                    flops_per_token: 6.0e9,
                    max_batch: 256,
                    rdma_bw: 25e9,
                    rdma_latency: SimTime::from_micros(2000),
                    pool_dram_bytes: 1500 * GB, // 2 TB/node minus headroom
                    pool_ssd_bytes: 3500 * GB,
                    ssd_bw: 6e9,
                },
            },
            // ---------------------------------------------------------
            // Qwen2-VL-72B: dense, TP8 (16 instances). GQA KV ≈ 320
            // KB/token spread over 8 GPUs. 640 GB − 146 GB weights −
            // ~60 GB act ⇒ ~1.36M KV tokens. Mixed VL reasoning:
            // avg 7615, max 40960 — the *most* skewed relative tail.
            // ---------------------------------------------------------
            TaskPreset::Qwen2Vl72b => WorkloadConfig {
                name: "qwen2-vl-72b",
                n_instances: 16,
                gpus_per_instance: 8,
                reqs_per_iter: 9600,
                group_size: 16,
                temperature: 0.8,
                max_gen_len: 40960,
                avg_gen_len: 7615,
                sigma_between: 1.25,
                sigma_within: 0.30,
                avg_prompt_len: 1800,
                sigma_prompt: 0.6,
                sd_richness: 0.95,
                hw: HardwareConfig {
                    kv_capacity_tokens: 1_360_000,
                    kv_bytes_per_token: 320 * 1024,
                    step_overhead: SimTime::from_micros(2500),
                    // 146 GB / (8 x 3.35 TB/s) = 5.4 ms.
                    weight_read_time: SimTime::from_micros(5400),
                    hbm_bw: 8.0 * 3.35 * TB_S,
                    // 8 x 700 TFLOPs x MFU 0.45 (dense TP8).
                    flops: 2520.0 * TFLOP_S,
                    flops_per_token: 144.0e9, // 2 x 72B
                    max_batch: 512,
                    rdma_bw: 8.0 * 25e9,
                    rdma_latency: SimTime::from_micros(2000),
                    pool_dram_bytes: 1500 * GB,
                    pool_ssd_bytes: 3500 * GB,
                    ssd_bw: 6e9,
                },
            },
            // ---------------------------------------------------------
            // Kimi-K2: 1T MoE (32B active), DP32+EP32 — 8 instances of 32
            // GPUs. MLA KV ≈ 70 KB/token. 2.56 TB − 1 TB weights −
            // ~300 GB act ⇒ ~18M KV tokens: *not* memory-constrained;
            // the bottleneck is the extreme tail (avg 38959, max 98304).
            // ---------------------------------------------------------
            TaskPreset::KimiK2 => WorkloadConfig {
                name: "kimi-k2",
                n_instances: 8,
                gpus_per_instance: 32,
                reqs_per_iter: 6400,
                group_size: 8,
                temperature: 1.0,
                max_gen_len: 98304,
                avg_gen_len: 38959,
                sigma_between: 0.85,
                sigma_within: 0.25,
                avg_prompt_len: 2000,
                sigma_prompt: 0.5,
                sd_richness: 0.85,
                hw: HardwareConfig {
                    kv_capacity_tokens: 40_000_000,
                    kv_bytes_per_token: 70 * 1024,
                    step_overhead: SimTime::from_micros(4000),
                    // EP all-to-all dominates: effective weight stream
                    // ~1 TB over 32 x 3.35 TB/s ⇒ ~9.3 ms + dispatch.
                    weight_read_time: SimTime::from_micros(12000),
                    hbm_bw: 32.0 * 3.35 * TB_S,
                    flops: 32.0 * 700.0 * 0.35 * TFLOP_S,
                    flops_per_token: 64.0e9, // 2 x 32B active
                    max_batch: 1024,
                    rdma_bw: 32.0 * 25e9,
                    rdma_latency: SimTime::from_micros(2500),
                    pool_dram_bytes: 1500 * GB,
                    pool_ssd_bytes: 3500 * GB,
                    ssd_bw: 6e9,
                },
            },
        }
    }

    /// A small, fast variant for unit/integration tests: 2–4 instances,
    /// tens-to-hundreds of requests, lengths in the hundreds — runs in
    /// milliseconds while keeping the same memory-pressure regime (the
    /// batch cap also shrinks, so capacity is tightened to keep
    /// Moonlight/Qwen memory-constrained).
    pub fn workload_for_test(&self) -> WorkloadConfig {
        match self {
            TaskPreset::Moonlight => {
                let mut c = self.workload().scaled(16, 64);
                c.hw.kv_capacity_tokens /= 4;
                c
            }
            TaskPreset::Qwen2Vl72b => {
                let mut c = self.workload().scaled(8, 32);
                c.hw.kv_capacity_tokens /= 4;
                c
            }
            TaskPreset::KimiK2 => self.workload().scaled(8, 64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for p in ALL_PRESETS {
            assert_eq!(TaskPreset::from_name(p.name()), Some(p));
        }
        assert_eq!(TaskPreset::from_name("nope"), None);
    }

    #[test]
    fn memory_pressure_regimes() {
        // Moonlight & Qwen are memory-constrained (capacity / (avg_len x
        // per-instance fair share of requests) < 1); Kimi-K2 is not.
        for (p, constrained) in [
            (TaskPreset::Moonlight, true),
            (TaskPreset::Qwen2Vl72b, true),
            (TaskPreset::KimiK2, false),
        ] {
            let c = p.workload();
            let fair_share =
                (c.reqs_per_iter / c.n_instances) as u64;
            let demand = fair_share * (c.avg_gen_len as u64 + c.avg_prompt_len as u64);
            let pressured = demand > c.hw.kv_capacity_tokens;
            assert_eq!(
                pressured, constrained,
                "{}: demand {demand} vs cap {}",
                c.name, c.hw.kv_capacity_tokens
            );
        }
    }

    #[test]
    fn test_variants_are_small() {
        for p in ALL_PRESETS {
            let c = p.workload_for_test();
            assert!(c.reqs_per_iter <= 1200, "{}", c.reqs_per_iter);
            assert!(c.max_gen_len <= 4096);
        }
    }
}
