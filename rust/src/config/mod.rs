//! System + workload configuration, including the paper's Table 3 task
//! presets (Moonlight, Qwen2-VL-72B, Kimi-K2) and scaled-down variants for
//! tests and CI.

pub mod presets;

pub use presets::{TaskPreset, ALL_PRESETS};

use crate::sim::clock::SimTime;

/// Workload characteristics of one RL task (paper Table 3) plus the
/// length-distribution calibration used by the generator (DESIGN.md §2).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub name: &'static str,
    /// Number of inference instances (= total GPUs / GPUs per instance).
    pub n_instances: usize,
    pub gpus_per_instance: usize,
    /// Requests per rollout iteration (= prompts × group size).
    pub reqs_per_iter: usize,
    /// GRPO group size G.
    pub group_size: usize,
    pub temperature: f64,
    /// Hard cap on generation length (tokens).
    pub max_gen_len: u32,
    /// Target mean generation length (tokens) used for calibration.
    pub avg_gen_len: u32,
    /// Log-normal sigma of the *group-mean* length distribution; larger =
    /// heavier tail (Figure 2's shape knob).
    pub sigma_between: f64,
    /// Log-normal sigma of lengths *within* a group around the group mean;
    /// small = strong intra-group correlation (Figure 4).
    pub sigma_within: f64,
    /// Prompt length distribution (log-normal, mean tokens / sigma).
    pub avg_prompt_len: u32,
    pub sigma_prompt: f64,
    /// Pattern richness of responses in (0, 1]: scales n-gram/CST SD
    /// acceptance (math CoT < templated judge output).
    pub sd_richness: f64,
    pub hw: HardwareConfig,
}

/// Per-instance hardware/cost-model constants. These are the simulator's
/// calibration knobs; DESIGN.md §2 documents how each maps to the paper's
/// H800 testbed.
#[derive(Debug, Clone)]
pub struct HardwareConfig {
    /// KVCache capacity per instance, in tokens.
    pub kv_capacity_tokens: u64,
    /// KVCache bytes per token (whole model, all layers).
    pub kv_bytes_per_token: u64,
    /// Fixed per-forward-step overhead (kernel launches, sampling, sync).
    pub step_overhead: SimTime,
    /// Time to stream the model weights once (memory-bound decode floor).
    pub weight_read_time: SimTime,
    /// HBM bandwidth available for KV reads, bytes/sec (aggregate over the
    /// instance's GPUs).
    pub hbm_bw: f64,
    /// Dense compute throughput, effective FLOP/s for prefill/verify.
    pub flops: f64,
    /// Model forward FLOPs per token (≈ 2 × active params).
    pub flops_per_token: f64,
    /// Max requests the engine will co-batch in one step.
    pub max_batch: usize,
    /// RDMA bandwidth between nodes for KV migration (bytes/sec) and the
    /// per-transfer latency — the Mooncake-style global pool.
    pub rdma_bw: f64,
    pub rdma_latency: SimTime,
    /// DRAM+SSD capacity of the global KV pool, per node, in bytes.
    pub pool_dram_bytes: u64,
    pub pool_ssd_bytes: u64,
    /// SSD bandwidth for pool spill (bytes/sec).
    pub ssd_bw: f64,
}

/// Coordinator/system behaviour knobs (scheduler + SD settings).
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Divided-rollout chunk size (tokens of generation per lease).
    pub chunk_size: u32,
    /// Paged-KV block size in tokens.
    pub kv_block_tokens: u32,
    /// Maximum draft length per request (paper: gamma_max = 8).
    pub gamma_max: u32,
    /// MBA priority factor (paper: lambda = 2).
    pub mba_lambda: f64,
    /// DGDS draft-client fetch interval.
    pub dgds_fetch_interval: SimTime,
    /// Scheduler re-plan interval for MBA gamma adaptation.
    pub mba_replan_interval: SimTime,
    /// Fraction of scheduling cycles that pick an underserved group
    /// regardless of the LFS estimate (anti-starvation safeguard, §3.3).
    pub starvation_guard_frac: f64,
    /// Target per-instance KV utilization the admission controller aims
    /// for (headroom below 1.0 avoids immediate preemptions).
    pub kv_target_util: f64,
    /// Fraction of live instances the `rollpacker` policy dedicates to
    /// tail-packing lanes (RollPacker-style stop-and-resume; ignored by
    /// every other scheduler). Clamped to at least one lane — and at
    /// least one general lane — whenever two or more instances are live.
    pub tail_lane_frac: f64,
    /// Bubble drafting (BubbleSpec-style): fraction of end-of-rollout
    /// idle-instance capacity redirected into extra draft generation for
    /// the remaining stragglers. When > 0 and some instances have
    /// drained with no request waiting, each still-busy instance's draft
    /// budget deepens toward `gamma_max` and the offloaded share of its
    /// draft cost leaves the critical path. 0.0 (the default) disables
    /// the mechanism entirely.
    pub bubble_draft_frac: f64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            chunk_size: 2048,
            kv_block_tokens: 64,
            gamma_max: 8,
            mba_lambda: 2.0,
            dgds_fetch_interval: SimTime::from_millis(200),
            mba_replan_interval: SimTime::from_secs(5),
            starvation_guard_frac: 0.05,
            kv_target_util: 0.92,
            tail_lane_frac: 0.25,
            bubble_draft_frac: 0.0,
        }
    }
}

/// How rollout and training phases interleave across epochs
/// (Laminar-style bounded-staleness pipelining).
///
/// * `Sync` — today's strictly synchronous loop: epoch *k*'s rollout
///   fully drains, then training + weight update run, then epoch *k+1*
///   starts. Every request trains on-policy.
/// * `Hybrid` — one-step overlap: epoch *k+1*'s rollout starts as soon
///   as epoch *k*'s rollout drains, running concurrently with epoch
///   *k*'s training/weight-update phases. Equivalent to `Async { lag: 1 }`
///   under a distinct name (the common deployment point).
/// * `Async { lag }` — bounded staleness: epoch *k*'s rollout may start
///   once the weight update from epoch *k − 1 − lag* has landed, so up
///   to `lag` training phases overlap generation. `lag = 0` reproduces
///   `Sync` byte-identically (pinned by test).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainingMode {
    Sync,
    Hybrid,
    Async { lag: u32 },
}

impl Default for TrainingMode {
    fn default() -> Self {
        TrainingMode::Sync
    }
}

impl TrainingMode {
    /// Parse a `--mode`/`--lag` pair ("sync" | "hybrid" | "async").
    /// `lag` is only meaningful for `async`; passing it with another
    /// mode is rejected so a typo cannot silently run synchronously.
    pub fn parse(mode: &str, lag: Option<u64>) -> anyhow::Result<TrainingMode> {
        match mode {
            "sync" => match lag {
                None => Ok(TrainingMode::Sync),
                Some(_) => anyhow::bail!("--lag only applies to --mode async"),
            },
            "hybrid" => match lag {
                None => Ok(TrainingMode::Hybrid),
                Some(_) => anyhow::bail!("--lag only applies to --mode async"),
            },
            "async" => {
                let lag = lag.unwrap_or(1);
                if lag > u32::MAX as u64 {
                    anyhow::bail!("--lag {lag} out of range");
                }
                Ok(TrainingMode::Async { lag: lag as u32 })
            }
            other => anyhow::bail!(
                "unknown training mode '{other}'; one of sync, hybrid, async"
            ),
        }
    }

    /// Off-policy version lag this mode admits (how many weight updates
    /// may still be in flight when a rollout starts).
    pub fn lag(&self) -> u32 {
        match self {
            TrainingMode::Sync => 0,
            TrainingMode::Hybrid => 1,
            TrainingMode::Async { lag } => *lag,
        }
    }

    /// The CLI/JSON name ("sync" | "hybrid" | "async").
    pub fn mode_str(&self) -> &'static str {
        match self {
            TrainingMode::Sync => "sync",
            TrainingMode::Hybrid => "hybrid",
            TrainingMode::Async { .. } => "async",
        }
    }

    /// True for the modes that run the suspend/resume stream path
    /// (everything except `Sync` — including `Async { lag: 0 }`, whose
    /// results must nonetheless match `Sync` byte-for-byte).
    pub fn is_pipelined(&self) -> bool {
        !matches!(self, TrainingMode::Sync)
    }

    /// Unambiguous report tag: `"sync"`, `"hybrid"`, or `"async:N"`
    /// with the lag bound embedded (sweep rows and experiment labels).
    pub fn tag(&self) -> String {
        match self {
            TrainingMode::Async { lag } => format!("async:{lag}"),
            m => m.mode_str().to_string(),
        }
    }
}

impl WorkloadConfig {
    /// Total KV bytes a fully-generated request of length `gen` (plus its
    /// prompt) occupies.
    pub fn kv_bytes(&self, prompt: u32, gen: u32) -> u64 {
        (prompt as u64 + gen as u64) * self.hw.kv_bytes_per_token
    }

    /// Number of prompt groups in one iteration.
    pub fn n_groups(&self) -> usize {
        self.reqs_per_iter / self.group_size
    }

    /// Scale the workload down for tests/CI: divide request count and
    /// instance count by `f`, and generation lengths by `len_f`, keeping
    /// per-instance memory pressure comparable.
    pub fn scaled(&self, f: usize, len_f: u32) -> WorkloadConfig {
        let mut c = self.clone();
        c.n_instances = (self.n_instances / f).max(2);
        c.reqs_per_iter =
            ((self.reqs_per_iter / f).max(2 * self.group_size) / self.group_size)
                * self.group_size;
        c.max_gen_len = (self.max_gen_len / len_f).max(64);
        c.avg_gen_len = (self.avg_gen_len / len_f).max(16);
        c.avg_prompt_len = (self.avg_prompt_len / len_f).max(8);
        c.hw.kv_capacity_tokens =
            (self.hw.kv_capacity_tokens / len_f as u64).max(1024);
        // max_batch is intentionally NOT scaled: the decode-vs-verify
        // compute regime (which decides where SD pays off) depends on
        // absolute batch size.
        c.hw.pool_dram_bytes /= len_f as u64;
        c.hw.pool_ssd_bytes /= len_f as u64;
        c
    }

    /// With a different GRPO group size (Figure 7 sweeps 8 vs 16), keeping
    /// the number of *requests* fixed.
    pub fn with_group_size(&self, g: usize) -> WorkloadConfig {
        let mut c = self.clone();
        c.group_size = g;
        c.reqs_per_iter = (self.reqs_per_iter / g).max(1) * g;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use presets::TaskPreset;

    #[test]
    fn presets_sane() {
        for p in ALL_PRESETS {
            let c = p.workload();
            assert!(c.n_instances >= 1);
            assert_eq!(c.reqs_per_iter % c.group_size, 0);
            assert!(c.avg_gen_len < c.max_gen_len);
            assert!(c.hw.kv_capacity_tokens > c.max_gen_len as u64);
            assert!(c.hw.flops > 0.0 && c.hw.hbm_bw > 0.0);
        }
    }

    #[test]
    fn table3_values_match_paper() {
        let m = TaskPreset::Moonlight.workload();
        assert_eq!(m.reqs_per_iter, 3200);
        assert_eq!(m.group_size, 8);
        assert_eq!(m.max_gen_len, 65536);
        assert_eq!(m.avg_gen_len, 22386);
        let q = TaskPreset::Qwen2Vl72b.workload();
        assert_eq!(q.n_instances, 16);
        assert_eq!(q.group_size, 16);
        assert_eq!(q.temperature, 0.8);
        let k = TaskPreset::KimiK2.workload();
        assert_eq!(k.gpus_per_instance, 32);
        assert_eq!(k.max_gen_len, 98304);
    }

    #[test]
    fn scaled_preserves_group_multiple() {
        let c = TaskPreset::Moonlight.workload().scaled(16, 32);
        assert_eq!(c.reqs_per_iter % c.group_size, 0);
        assert!(c.n_instances >= 2);
        assert!(c.avg_gen_len >= 16);
    }

    #[test]
    fn with_group_size_keeps_requests() {
        let c = TaskPreset::Moonlight.workload().with_group_size(16);
        assert_eq!(c.group_size, 16);
        assert_eq!(c.reqs_per_iter % 16, 0);
    }

    #[test]
    fn training_mode_parses_and_round_trips() {
        assert_eq!(TrainingMode::parse("sync", None).unwrap(), TrainingMode::Sync);
        assert_eq!(
            TrainingMode::parse("hybrid", None).unwrap(),
            TrainingMode::Hybrid
        );
        assert_eq!(
            TrainingMode::parse("async", None).unwrap(),
            TrainingMode::Async { lag: 1 }
        );
        assert_eq!(
            TrainingMode::parse("async", Some(0)).unwrap(),
            TrainingMode::Async { lag: 0 }
        );
        assert_eq!(TrainingMode::Sync.lag(), 0);
        assert_eq!(TrainingMode::Hybrid.lag(), 1);
        assert_eq!(TrainingMode::Async { lag: 3 }.lag(), 3);
        assert!(!TrainingMode::Sync.is_pipelined());
        assert!(TrainingMode::Async { lag: 0 }.is_pipelined());
        for (m, s) in [
            (TrainingMode::Sync, "sync"),
            (TrainingMode::Hybrid, "hybrid"),
            (TrainingMode::Async { lag: 2 }, "async"),
        ] {
            assert_eq!(m.mode_str(), s);
        }
        assert_eq!(TrainingMode::Sync.tag(), "sync");
        assert_eq!(TrainingMode::Hybrid.tag(), "hybrid");
        assert_eq!(TrainingMode::Async { lag: 2 }.tag(), "async:2");
        assert!(TrainingMode::parse("laminar", None).is_err());
        assert!(TrainingMode::parse("sync", Some(1)).is_err());
        assert!(TrainingMode::parse("hybrid", Some(2)).is_err());
    }
}
