//! The cluster rollout simulation driver.
//!
//! Owns the instance fleet, the request buffer, the global KV pool, the
//! active scheduling policy and SD strategy, and advances virtual time
//! with a discrete-event loop. The coordinator/scheduler/spec code under
//! test is the production code; only token generation is replaced by the
//! fluid expected-rate model (DESIGN.md §2).
//!
//! This is the simulated substrate behind the unified session API —
//! construct runs through [`crate::rollout::RolloutSession`] rather than
//! driving `ClusterSim` directly; lifecycle transitions stream to the
//! session's observers.
//!
//! The fleet is *elastic*: a [`FaultPlan`] attached via
//! [`ClusterSim::with_faults`] replays instance crashes, stragglers,
//! recoveries, scale events and request aborts at exact virtual
//! timestamps. A lost instance's in-flight requests drain back through
//! the divided-rollout re-queue path (the scheduler hears about it via
//! [`Scheduler::on_instance_lost`], preserving in-flight progress in the
//! context manager), so faults change *when* requests finish, never
//! *whether* — every request completes or is explicitly aborted.

use std::time::Instant;

use crate::config::{SystemConfig, WorkloadConfig};
use crate::coordinator::{KvLocation, Phase, RequestBuffer};
use crate::engine::costmodel::CostModel;
use crate::engine::instance::{Instance, Interval, RunningReq};
use crate::kvcache::GlobalKvPool;
use crate::metrics::{Completion, LoadSample, RolloutMetrics};
use crate::rollout::observer::{ObserverHub, RolloutEvent};
use crate::scheduler::{Assignment, InstanceView, SchedCtx, Scheduler};
use crate::sim::clock::SimTime;
use crate::sim::events::EventQueue;
use crate::sim::faults::{FaultEvent, FaultPlan};
use crate::spec::mba::{mba_allocate, MbaInputs};
use crate::spec::simmodel::{SdStrategy, SpecCtx, SpecSim};
use crate::workload::{GroupSpec, InstanceId, RequestId};

/// Events driving the simulation.
#[derive(Debug, Clone, Copy)]
enum Event {
    /// End of a planned macro-interval on an instance.
    Wake { instance: InstanceId, epoch: u64 },
    /// A scheduled request's KV transfer / (re)prefill completed.
    /// `chunk_seq` is the request's `chunks_run` at scheduling time;
    /// arrivals from leases revoked by a fault drain are stale and
    /// ignored (the drain may have re-scheduled the request already).
    Arrive { req: RequestId, chunk_seq: u32 },
    /// Periodic telemetry sampling.
    Sample,
    /// A scripted fault fires (index into the attached `FaultPlan`).
    Fault { idx: usize },
}

/// Result of a rollout run.
pub struct RolloutOutcome {
    pub metrics: RolloutMetrics,
    pub buffer: RequestBuffer,
}

/// Per-group live progress used for SD context (how many reference
/// streams the CST would hold).
#[derive(Debug, Clone, Copy, Default)]
struct GroupProgress {
    finished: usize,
    running: usize,
    /// Reference streams the group CST holds from *previous* iterations
    /// (cross-iteration warm start), already discounted by the store.
    warm_refs: usize,
    /// The group entered this rollout with a warm length prior (mirrors
    /// the scheduler's `has_context` while nothing has finished yet).
    warm_ctx: bool,
}

/// Per-interval bubble-drafting terms (BubbleSpec-style): set at plan
/// time when end-of-rollout idle capacity backs this instance's draft
/// generation, consumed at commit time scaled by the steps actually
/// run. Zeroed whenever an interval plans without an active bubble, so
/// stale terms never leak into later intervals.
#[derive(Debug, Clone, Copy, Default)]
struct BubbleStep {
    /// Draft seconds offloaded to idle instances, per engine step.
    draft_secs: f64,
    /// Expected extra accepted tokens per step (Σ over the batch of the
    /// γ-uplift acceptance-rate delta).
    rate_delta: f64,
}

/// Wall-time attribution of the event loop (`seer rollout --profile`):
/// where the host CPU goes, without reaching for an external profiler.
/// Collected only when profiling is enabled — the disabled path costs
/// one branch per section. Never feeds the report (reports carry virtual
/// time only); the breakdown prints to stderr at the end of the run.
#[derive(Debug, Default)]
struct ProfileStats {
    /// Events popped from the queue.
    events: u64,
    /// Scheduling passes that actually ran (`schedule_dirty` and a
    /// non-empty waiting set).
    sched_passes: u64,
    /// Wall nanoseconds inside `Scheduler::schedule`.
    sched_ns: u64,
    /// Σ waiting-set size at pass start (mean = `/ sched_passes`).
    waiting_sum: u64,
    /// Assignments produced across all passes.
    assignments: u64,
    commit_calls: u64,
    commit_ns: u64,
    plan_calls: u64,
    plan_ns: u64,
    /// Observer emissions (time also counted inside whichever section
    /// fired them).
    emit_events: u64,
    emit_ns: u64,
}

impl ProfileStats {
    fn report(&self) {
        use crate::util::bench::fmt_ns;
        let mean_wait = if self.sched_passes > 0 {
            self.waiting_sum as f64 / self.sched_passes as f64
        } else {
            0.0
        };
        eprintln!("[profile] events processed: {}", self.events);
        eprintln!(
            "[profile] scheduler: {} passes, {} total ({} / pass), mean \
             waiting-set {:.1}, {} assignments",
            self.sched_passes,
            fmt_ns(self.sched_ns as f64),
            fmt_ns(self.sched_ns as f64 / self.sched_passes.max(1) as f64),
            mean_wait,
            self.assignments,
        );
        eprintln!(
            "[profile] engine commit: {} calls, {} total ({} / call)",
            self.commit_calls,
            fmt_ns(self.commit_ns as f64),
            fmt_ns(self.commit_ns as f64 / self.commit_calls.max(1) as f64),
        );
        eprintln!(
            "[profile] interval planning: {} calls, {} total ({} / call)",
            self.plan_calls,
            fmt_ns(self.plan_ns as f64),
            fmt_ns(self.plan_ns as f64 / self.plan_calls.max(1) as f64),
        );
        eprintln!(
            "[profile] observer emission: {} events, {} total (already \
             included in the sections that fired them)",
            self.emit_events,
            fmt_ns(self.emit_ns as f64),
        );
    }
}

pub struct ClusterSim {
    cfg: WorkloadConfig,
    sys: SystemConfig,
    cost: CostModel,
    instances: Vec<Instance>,
    buffer: RequestBuffer,
    pool: GlobalKvPool,
    scheduler: Box<dyn Scheduler>,
    spec: SpecSim,
    metrics: RolloutMetrics,
    queue: EventQueue<Event>,
    /// Per-group live progress, indexed by `GroupId` (group ids are
    /// contiguous from 0 by construction — asserted in `new`).
    group_progress: Vec<GroupProgress>,
    /// Last instance each request ran on (for migration counting),
    /// indexed by `RequestId`.
    last_instance: Vec<Option<InstanceId>>,
    /// Partial Rollout: stop after this many completions.
    stop_after: Option<usize>,
    sample_interval: SimTime,
    /// Telemetry bound: once `load_samples` would exceed this, the
    /// recorded series is decimated (every other kept tick dropped) and
    /// the recording stride doubles — long runs stay O(cap) memory while
    /// every derived report metric (none read `load_samples`) stays
    /// bit-identical. Deterministic: driven by virtual-time tick counts
    /// only.
    max_load_samples: usize,
    /// Current recording stride over telemetry ticks (powers of two).
    sample_stride: u64,
    /// Telemetry ticks seen at base cadence.
    sample_ticks: u64,
    /// `(tick, start index in load_samples)` per *recorded* tick — the
    /// decimation block boundaries (fleet size can change mid-run, so
    /// blocks are not uniform).
    load_ticks: Vec<(u64, u32)>,
    /// Acceptance-length bookkeeping: Σ rate·steps and Σ steps over all
    /// running request-intervals (for the τ metric).
    accept_len_weighted: f64,
    accept_steps: f64,
    /// Policy drift since the warm-start priors were recorded (0 when
    /// cold or same-policy). Discounts warm reference streams in the SD
    /// acceptance model — RhymeRL-style history replay: old-policy
    /// streams draft well while the policy still rhymes with the one
    /// that produced them, and fade as it moves.
    warm_drift: f64,
    /// Per-instance bubble-drafting terms for the interval in flight,
    /// indexed by instance (dense side table, resized on scale-up).
    bubble_interval: Vec<BubbleStep>,
    /// Σ virtual draft seconds offloaded to idle instances.
    bubble_draft_secs: f64,
    /// Σ expected extra accepted tokens from bubble γ uplift.
    bubble_accept_est: f64,
    /// Upper bound on events (runaway guard).
    max_events: u64,
    /// Events processed so far (stepping keeps the runaway guard and the
    /// SEER_DEBUG cadence across `step_until` segments).
    events: u64,
    /// Whether [`ClusterSim::start`] already primed the queue (faults,
    /// first scheduling pass, telemetry cadence).
    started: bool,
    /// Policy version stamped onto completions as they finish. The
    /// single-shot `run` path leaves it 0 (synchronous: one version per
    /// rollout); the suspend/resume stream path bumps it live as
    /// overlapped weight updates land mid-rollout.
    policy_version: u64,
    /// Per-instance accumulated live time (closed intervals) and the
    /// open-interval start, if the instance is currently part of the
    /// fleet. Feeds `RolloutMetrics::live_time` so utilization divides
    /// each instance's busy time by the span it actually existed.
    live_acc: Vec<SimTime>,
    live_since: Vec<Option<SimTime>>,
    schedule_dirty: bool,
    /// Streaming lifecycle-event sinks (the session layer's observer
    /// API); empty by default and free when empty.
    observers: ObserverHub,
    /// Scripted faults, replayed at their virtual timestamps.
    faults: FaultPlan,
    /// Unfired `InstanceRecover`/`ScaleUp` events (deadlock detection: a
    /// fully downed fleet may still be revived by one of these; other
    /// pending faults cannot bring capacity back).
    revivals_remaining: usize,
    /// Requests drained off a lost instance, with the fault time —
    /// cleared (and counted into recovery latency) at re-admission.
    /// Indexed by `RequestId`.
    drained_by_fault: Vec<Option<SimTime>>,
    /// Completions so far (the Partial Rollout stop threshold; aborted
    /// requests are terminal but do NOT count toward it).
    n_completed: usize,
    /// Run cross-cutting invariant checks at every telemetry sample
    /// (property-test harness; off by default).
    verify_invariants: bool,
    /// Wall-time attribution (`--profile`); `None` = disabled, free.
    profile: Option<Box<ProfileStats>>,
    /// Reusable scheduling-pass scratch (instance views + assignments):
    /// the steady-state loop allocates nothing.
    views_scratch: Vec<InstanceView>,
    assign_scratch: Vec<Assignment>,
}

impl ClusterSim {
    pub fn new(
        cfg: WorkloadConfig,
        sys: SystemConfig,
        groups: Vec<GroupSpec>,
        mut scheduler: Box<dyn Scheduler>,
        sd: SdStrategy,
    ) -> Self {
        scheduler.init(&groups, &cfg, &sys);
        let buffer = RequestBuffer::from_groups(&groups);
        let instances = (0..cfg.n_instances)
            .map(|i| {
                Instance::new(
                    InstanceId(i as u32),
                    cfg.hw.kv_capacity_tokens,
                    sys.kv_block_tokens,
                )
            })
            .collect();
        let pool = GlobalKvPool::new(&cfg.hw, cfg.n_instances.max(1));
        let metrics = RolloutMetrics::new(cfg.n_instances);
        // Dense side tables: group and request ids are contiguous from 0
        // by construction (the buffer asserts request-id contiguity).
        let mut group_progress = Vec::with_capacity(groups.len());
        for (gi, g) in groups.iter().enumerate() {
            debug_assert_eq!(
                g.id.0 as usize, gi,
                "group ids must be contiguous"
            );
            group_progress.push(GroupProgress::default());
        }
        let n_reqs = buffer.len();
        let n_inst = instances.len();
        ClusterSim {
            cost: CostModel::new(&cfg.hw),
            spec: SpecSim::new(sd).with_richness(cfg.sd_richness),
            cfg,
            sys,
            instances,
            buffer,
            pool,
            scheduler,
            metrics,
            queue: EventQueue::new(),
            group_progress,
            last_instance: vec![None; n_reqs],
            stop_after: None,
            sample_interval: SimTime::from_secs(10),
            max_load_samples: 16_384,
            sample_stride: 1,
            sample_ticks: 0,
            load_ticks: Vec::new(),
            accept_len_weighted: 0.0,
            accept_steps: 0.0,
            warm_drift: 0.0,
            bubble_interval: vec![BubbleStep::default(); n_inst],
            bubble_draft_secs: 0.0,
            bubble_accept_est: 0.0,
            max_events: 50_000_000,
            events: 0,
            started: false,
            policy_version: 0,
            live_acc: vec![SimTime::ZERO; n_inst],
            live_since: vec![Some(SimTime::ZERO); n_inst],
            schedule_dirty: true,
            observers: ObserverHub::new(),
            faults: FaultPlan::default(),
            revivals_remaining: 0,
            drained_by_fault: vec![None; n_reqs],
            n_completed: 0,
            verify_invariants: false,
            profile: None,
            views_scratch: Vec::new(),
            assign_scratch: Vec::new(),
        }
    }

    /// Attach the streaming observers events are narrated into.
    pub fn with_observers(mut self, observers: ObserverHub) -> Self {
        self.observers = observers;
        self
    }

    /// Attach a deterministic fault & elasticity script. Events replay at
    /// their exact virtual timestamps; same seed + same plan ⇒ same
    /// event trace. Panics on a structurally invalid plan (bad factors,
    /// zero-sized scale events) — a scripting bug, not a result.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        plan.validate().expect("invalid fault plan");
        let plan = plan.sorted();
        self.revivals_remaining = plan
            .events
            .iter()
            .filter(|e| {
                matches!(
                    e.event,
                    FaultEvent::InstanceRecover { .. }
                        | FaultEvent::ScaleUp { .. }
                )
            })
            .count();
        self.faults = plan;
        self
    }

    /// Enable per-sample runtime invariant checks (KV pool accounting,
    /// per-instance concurrency ≤ batch cap, allocator within capacity,
    /// down instances empty). Used by the property harness; costs one
    /// fleet scan per telemetry sample.
    pub fn with_invariant_checks(mut self) -> Self {
        self.verify_invariants = true;
        self
    }

    /// Inject cross-iteration warm-start context: the scheduler receives
    /// the length priors (via [`Scheduler::warm_start`]) and the SD model
    /// starts each group with its historical reference-stream count
    /// instead of zero. A no-op with empty priors.
    ///
    /// `drift` is the policy drift (epoch-drift sigma) accumulated since
    /// the priors were recorded: warm reference streams are discounted
    /// by it inside the acceptance model ([`SpecCtx::effective_refs`]),
    /// so same-policy replay drafts like fresh siblings while
    /// far-drifted history is worth nothing. Fresh in-rollout siblings
    /// are never discounted.
    pub fn with_warm_context(
        mut self,
        priors: &crate::iteration::ContextPriors,
        drift: f64,
    ) -> Self {
        self.warm_drift = drift.max(0.0);
        let consumed = self.scheduler.warm_start(priors);
        // Warm reference streams model CST *contents*, which exist
        // independent of the scheduling policy — they apply even when a
        // history-free policy discards the length priors.
        for (g, refs) in &priors.warm_refs {
            if let Some(gp) = self.group_progress.get_mut(g.0 as usize) {
                gp.warm_refs = *refs;
            }
        }
        // Probe SD *priority*, by contrast, mirrors the scheduler's
        // probe-skip decision: it only changes when the policy actually
        // consumed the priors, so history-free policies schedule and
        // prioritize identically warm or cold.
        if consumed {
            for (g, _) in &priors.estimates {
                if let Some(gp) = self.group_progress.get_mut(g.0 as usize) {
                    gp.warm_ctx = true;
                }
            }
        }
        self
    }

    /// Partial Rollout mode: terminate the iteration after `n`
    /// completions (remaining requests carry over — §4.4.3).
    pub fn stop_after(mut self, n: usize) -> Self {
        self.stop_after = Some(n);
        self
    }

    pub fn sample_interval(mut self, t: SimTime) -> Self {
        self.sample_interval = t;
        self
    }

    /// Cap the recorded telemetry series (see the field docs); the
    /// default keeps ~16k samples. Reports never read `load_samples`, so
    /// this only affects diagnostic time-series output.
    pub fn max_load_samples(mut self, n: usize) -> Self {
        self.max_load_samples = n.max(1);
        self
    }

    /// Collect a wall-time breakdown of the event loop (scheduler passes
    /// vs engine commit/plan vs observer emission) and print it to
    /// stderr when the run completes — `seer rollout --profile`. Wall
    /// clock never enters the report, so profiling cannot perturb
    /// results, only narrate them.
    pub fn with_profiling(mut self) -> Self {
        self.profile = Some(Box::default());
        self
    }

    /// Run the rollout to completion. Panics if the event loop stalls
    /// (a scheduling deadlock — treated as a bug, not a result).
    ///
    /// This is exactly `start()` + `step_until(FAR_FUTURE)` + `finish()`
    /// — the suspend/resume stream path
    /// ([`crate::rollout::RolloutStream`]) composes the same three
    /// primitives with finite deadlines, so a single-shot run and a
    /// never-suspended stream execute the identical event sequence.
    pub fn run(mut self) -> RolloutOutcome {
        self.start();
        self.step_until(SimTime::FAR_FUTURE);
        self.finish()
    }

    /// Prime the event queue: pin every scripted fault to its virtual
    /// timestamp up front, in plan order (the queue's FIFO tie-break
    /// preserves authored order for same-timestamp events —
    /// determinism), run the first scheduling pass, and start the
    /// telemetry cadence. Idempotent: only the first call does anything.
    pub fn start(&mut self) {
        if self.started {
            return;
        }
        self.started = true;
        for (idx, f) in self.faults.events.iter().enumerate() {
            self.queue.schedule_at(f.at, Event::Fault { idx });
        }
        self.try_schedule();
        self.queue.schedule_in(self.sample_interval, Event::Sample);
    }

    /// Advance the event loop, processing every event with virtual
    /// timestamp ≤ `deadline` (events *at* the deadline are processed —
    /// a weight update landing exactly at an event's timestamp sees that
    /// event's completions stamped with the pre-update version). Returns
    /// `true` when the rollout finished, `false` when it paused at the
    /// deadline with work still in flight. Panics if the event loop
    /// stalls (a scheduling deadlock — treated as a bug, not a result).
    pub fn step_until(&mut self, deadline: SimTime) -> bool {
        debug_assert!(self.started, "step_until before start");
        let debug = std::env::var("SEER_DEBUG").is_ok();
        while !self.done() {
            if debug && self.events % 200_000 == 0 && self.events > 0 {
                eprintln!(
                    "[sim] events={} t={:.1}s finished={}/{} waiting={} preempt={} tokens={}",
                    self.events,
                    self.queue.now().as_secs_f64(),
                    self.buffer.n_finished(),
                    self.buffer.len(),
                    self.buffer.n_waiting(),
                    self.metrics.preemptions,
                    self.metrics.tokens_generated,
                );
                for inst in &self.instances {
                    eprintln!(
                        "  [inst {}] running={} pending={} used={}/{} free_tok={} interval={:?}",
                        inst.id.0,
                        inst.running.len(),
                        inst.pending.len(),
                        inst.alloc.used_blocks(),
                        inst.alloc.capacity_blocks(),
                        inst.alloc.free_tokens(),
                        inst.interval.map(|iv| (iv.step_us, iv.steps)),
                    );
                }
            }
            match self.queue.peek_time() {
                Some(t) if t > deadline => return false,
                Some(_) => {}
                None => {
                    // Nothing in flight but requests remain: scheduling
                    // must make progress, otherwise the configuration is
                    // infeasible.
                    self.schedule_dirty = true;
                    self.try_schedule();
                    if self.queue.is_empty() {
                        panic!(
                            "rollout stalled: {} waiting, {} finished of {}",
                            self.buffer.n_waiting(),
                            self.buffer.n_finished(),
                            self.buffer.len()
                        );
                    }
                    continue;
                }
            }
            let ev = self.queue.pop().expect("peeked event vanished");
            self.events += 1;
            assert!(
                self.events < self.max_events,
                "event budget exceeded — runaway simulation"
            );
            if let Some(p) = self.profile.as_deref_mut() {
                p.events += 1;
            }
            let now = self.queue.now();
            self.handle_event(ev.payload, now);
        }
        true
    }

    /// Finalize metrics and hand the outcome back. The counterpart of
    /// `step_until` returning `true`.
    pub fn finish(mut self) -> RolloutOutcome {
        self.finalize();
        RolloutOutcome {
            metrics: self.metrics,
            buffer: self.buffer,
        }
    }

    /// Set the policy version stamped onto completions from now on. The
    /// stream path calls this as overlapped weight updates land
    /// mid-rollout; the single-shot path never does (every completion
    /// stays at version 0 — one policy per synchronous rollout).
    pub fn set_policy_version(&mut self, v: u64) {
        self.policy_version = v;
    }

    /// Dispatch one popped event.
    fn handle_event(&mut self, ev: Event, now: SimTime) {
        match ev {
            Event::Wake { instance, epoch } => {
                let idx = instance.0 as usize;
                if self.instances[idx].epoch != epoch {
                    return; // stale wake
                }
                self.commit_and_handle(idx, now);
                self.try_schedule();
                self.plan_interval(idx, now);
            }
            Event::Arrive { req, chunk_seq } => {
                self.handle_arrival(req, chunk_seq, now);
            }
            Event::Sample => {
                self.record_sample(now);
                if self.verify_invariants {
                    self.assert_runtime_invariants();
                }
                if !self.done() {
                    // A fully downed fleet with no recover/scale-up
                    // left to revive it can never finish: fail
                    // loudly instead of sampling forever.
                    assert!(
                        self.instances.iter().any(|i| i.up)
                            || self.revivals_remaining > 0,
                        "fault plan leaves no live instances with {} \
                         requests unfinished",
                        self.buffer.n_waiting()
                    );
                    self.queue
                        .schedule_in(self.sample_interval, Event::Sample);
                }
            }
            Event::Fault { idx } => {
                let fault = self.faults.events[idx].event;
                if matches!(
                    fault,
                    FaultEvent::InstanceRecover { .. }
                        | FaultEvent::ScaleUp { .. }
                ) {
                    self.revivals_remaining -= 1;
                }
                self.apply_fault(fault, now);
            }
        }
    }

    fn done(&self) -> bool {
        if let Some(n) = self.stop_after {
            // Count *completions* (each pushed exactly once by
            // `finish_request`), never phase scans: a request re-queued
            // by migration or a fault drain must not be double-counted
            // toward the Partial Rollout threshold, and fault-aborted
            // requests (phase-finished but never completed) must not
            // count at all.
            if self.n_completed >= n {
                return true;
            }
        }
        self.buffer.all_finished()
    }

    fn finalize(&mut self) {
        let last_completion = self
            .metrics
            .completions
            .iter()
            .map(|c| c.finished_at)
            .max()
            .unwrap_or(SimTime::ZERO);
        self.metrics.makespan = last_completion;
        for (i, inst) in self.instances.iter().enumerate() {
            self.metrics.busy_time[i] = inst.busy;
            self.metrics.engine_steps += inst.steps_total;
        }
        // Close every open live interval at the makespan: an instance
        // live at the end was live for `makespan − joined`, and a
        // scale-up that landed after the last completion contributes
        // nothing (saturating).
        for (i, open) in self.live_since.iter_mut().enumerate() {
            if let Some(s) = open.take() {
                self.live_acc[i] += last_completion.saturating_sub(s);
            }
        }
        self.metrics.live_time = std::mem::take(&mut self.live_acc);
        self.metrics.tau = if self.accept_steps > 0.0 {
            self.accept_len_weighted / self.accept_steps
        } else {
            1.0
        };
        let (tail_packed, tail_resume) = self.scheduler.tail_stats();
        self.metrics.tail_packed = tail_packed;
        self.metrics.tail_resume_tokens = tail_resume;
        self.metrics.bubble_draft_time =
            SimTime::from_secs_f64(self.bubble_draft_secs);
        self.metrics.bubble_accept_tokens =
            self.bubble_accept_est.round() as u64;
        if self.verify_invariants {
            self.assert_runtime_invariants();
        }
        if let Some(p) = &self.profile {
            p.report();
        }
    }

    // ------------------------------------------------------------------
    // Fault & elasticity layer.
    // ------------------------------------------------------------------

    fn live_instance_ids(&self) -> Vec<InstanceId> {
        self.instances
            .iter()
            .filter(|i| i.up)
            .map(|i| i.id)
            .collect()
    }

    fn apply_fault(&mut self, fault: FaultEvent, now: SimTime) {
        match fault {
            FaultEvent::InstanceDown { instance } => {
                self.fault_down(instance, now);
            }
            FaultEvent::InstanceSlowdown { instance, factor } => {
                let idx = instance.0 as usize;
                if idx >= self.instances.len() || !self.instances[idx].up {
                    return;
                }
                // Close the in-flight interval at the old speed, then
                // re-plan: the slowdown takes effect immediately.
                self.commit_and_handle(idx, now);
                self.instances[idx].slow_factor = factor.max(0.01);
                self.try_schedule();
                self.plan_interval(idx, now);
            }
            FaultEvent::InstanceRecover { instance } => {
                let idx = instance.0 as usize;
                if idx >= self.instances.len() {
                    return;
                }
                let (up, slow) =
                    (self.instances[idx].up, self.instances[idx].slow_factor);
                if up && slow == 1.0 {
                    return; // nothing to recover from
                }
                if up {
                    // Straggler back to full speed: re-price the batch.
                    self.commit_and_handle(idx, now);
                    self.instances[idx].slow_factor = 1.0;
                    self.try_schedule();
                    self.plan_interval(idx, now);
                    return;
                }
                let inst = &mut self.instances[idx];
                inst.up = true;
                inst.slow_factor = 1.0;
                inst.epoch += 1;
                // Reopen the live interval: downtime does not count
                // against this instance's utilization denominator.
                self.live_since[idx] = Some(now);
                // Recovery is capacity arriving, exactly like scale-up:
                // without this hook a pinned policy would leave the
                // recovered instance idle (its groups were re-homed at
                // loss time), and groups still pinned to a dead
                // instance — possible after a fully-downed interval —
                // would starve forever.
                let live = self.live_instance_ids();
                self.scheduler
                    .on_instances_added(&[instance], &live, &self.buffer);
                self.schedule_dirty = true;
                self.try_schedule();
            }
            FaultEvent::ScaleUp { n } => {
                let start = self.instances.len();
                for i in 0..n {
                    self.instances.push(Instance::new(
                        InstanceId((start + i) as u32),
                        self.cfg.hw.kv_capacity_tokens,
                        self.sys.kv_block_tokens,
                    ));
                }
                self.metrics
                    .busy_time
                    .resize(self.instances.len(), SimTime::ZERO);
                self.bubble_interval
                    .resize(self.instances.len(), BubbleStep::default());
                // Late joiners' live intervals open now, not at t=0.
                self.live_acc.resize(self.instances.len(), SimTime::ZERO);
                self.live_since.resize(self.instances.len(), Some(now));
                self.metrics.instances_added += n as u64;
                let added: Vec<InstanceId> = (start..start + n)
                    .map(|i| InstanceId(i as u32))
                    .collect();
                let live = self.live_instance_ids();
                self.scheduler
                    .on_instances_added(&added, &live, &self.buffer);
                self.schedule_dirty = true;
                self.try_schedule();
            }
            FaultEvent::ScaleDown { n } => {
                // Reclaim the highest-indexed live instances, never the
                // whole fleet: a scale-down below one instance is
                // clamped (unlike a crash, reclamation is voluntary).
                let live: Vec<usize> = self
                    .instances
                    .iter()
                    .enumerate()
                    .filter(|(_, i)| i.up)
                    .map(|(idx, _)| idx)
                    .collect();
                let n = n.min(live.len().saturating_sub(1));
                for &idx in live.iter().rev().take(n) {
                    self.fault_down(InstanceId(idx as u32), now);
                }
            }
            FaultEvent::RequestAbort { req } => {
                self.abort_request(req, now);
            }
            // Trainer-side events never touch the rollout cluster: the
            // training driver's pipeline recurrence replays them via
            // `sim::faults::trainer_step`. Ignoring them here lets one
            // `--faults` script cover both failure domains.
            FaultEvent::TrainerSlowdown { .. }
            | FaultEvent::TrainerStall { .. }
            | FaultEvent::TrainerCrash { .. } => {}
        }
    }

    /// An instance dies (crash or reclamation): its uncommitted interval
    /// progress is discarded (the coordinator never saw those tokens —
    /// they must be re-generated), its HBM-resident KV is lost, and its
    /// in-flight requests drain back into the waiting queue through the
    /// divided-rollout re-queue path.
    fn fault_down(&mut self, id: InstanceId, now: SimTime) {
        let idx = id.0 as usize;
        if idx >= self.instances.len() || !self.instances[idx].up {
            return;
        }
        // Commit-and-discard: the interval's elapsed time was really
        // spent (busy/steps accounting stands) but its token gains die
        // with the instance.
        let doomed = self.instances[idx].commit_until(now);
        let lost: u64 = doomed.gained.iter().map(|(_, g)| *g as u64).sum();
        self.metrics.fault_lost_tokens += lost;

        // Close the live interval: from here until recovery (if any)
        // this instance is not part of the fleet.
        if let Some(s) = self.live_since[idx].take() {
            self.live_acc[idx] += now.saturating_sub(s);
        }
        let inst = &mut self.instances[idx];
        inst.up = false;
        inst.slow_factor = 1.0;
        inst.epoch += 1;
        let running: Vec<RequestId> = inst.running.keys().copied().collect();
        let pending: Vec<RequestId> = inst.pending.keys().copied().collect();
        inst.running.clear();
        inst.pending.clear();
        let mut drained: Vec<RequestId> = Vec::new();
        for rid in running.iter().chain(pending.iter()).copied() {
            self.instances[idx].alloc.release(rid);
            // The pool never holds a copy for a resident request (fetch
            // removes entries), so the KV is simply gone: full
            // re-prefill of prompt + committed progress on re-admission.
            self.pool.remove(rid);
            let r = self.buffer.get_mut(rid);
            r.kv_tokens = 0;
            r.kv_location = KvLocation::Nowhere;
            r.needs_reprefill = true;
            self.buffer.mark_waiting(rid);
            self.metrics.fault_requeued += 1;
            self.drained_by_fault[rid.0 as usize] = Some(now);
            drained.push(rid);
        }
        // Only resident requests counted toward group concurrency;
        // pending ones never arrived.
        for rid in &running {
            let group = self.buffer.get(*rid).group();
            let gp = &mut self.group_progress[group.0 as usize];
            gp.running = gp.running.saturating_sub(1);
        }
        self.metrics.instances_lost += 1;
        let live = self.live_instance_ids();
        // The policy hears about the loss *after* the buffer reflects
        // it: the default hook routes drained requests through
        // on_chunk_end (context-manager progress preservation), pinned
        // policies re-home the lost instance's queue.
        self.scheduler
            .on_instance_lost(id, &drained, &live, &self.buffer);
        self.emit_event(RolloutEvent::InstanceLost {
            instance: id,
            drained: drained.len() as u32,
            now,
        });
        self.schedule_dirty = true;
        self.try_schedule();
    }

    /// Scripted request abort: terminal, excluded from completions. A
    /// no-op for unknown or already-terminal requests.
    fn abort_request(&mut self, req: RequestId, now: SimTime) {
        if req.0 as usize >= self.buffer.len() {
            return;
        }
        if self.buffer.get(req).is_finished() {
            return;
        }
        let mut replan: Option<usize> = None;
        if let Phase::Running(inst_id) = self.buffer.get(req).phase {
            let idx = inst_id.0 as usize;
            // Close the in-flight interval so batchmates keep their
            // progress; the commit may finish or park the victim itself.
            // Either way the interval is gone, so this instance must be
            // re-planned below or its resident batch would stall.
            self.commit_and_handle(idx, now);
            replan = Some(idx);
            if let Phase::Running(_) = self.buffer.get(req).phase {
                let inst = &mut self.instances[idx];
                let was_resident = inst.running.remove(&req).is_some();
                inst.pending.remove(&req);
                inst.epoch += 1;
                inst.alloc.release(req);
                if was_resident {
                    let group = self.buffer.get(req).group();
                    let gp = &mut self.group_progress[group.0 as usize];
                    gp.running = gp.running.saturating_sub(1);
                }
            }
        }
        // The commit above may have finished the request on its own —
        // then there is nothing left to abort.
        if !self.buffer.get(req).is_finished() {
            self.pool.remove(req);
            let generated = self.buffer.get(req).generated;
            if matches!(self.buffer.get(req).phase, Phase::Running(_)) {
                // Taken off an instance above; route through Waiting so
                // the buffer's phase/set bookkeeping stays consistent.
                self.buffer.mark_waiting(req);
            }
            self.buffer.mark_aborted(req);
            self.metrics.aborted += 1;
            self.drained_by_fault[req.0 as usize] = None;
            self.emit_event(RolloutEvent::Aborted { req, generated, now });
        }
        self.schedule_dirty = true;
        self.try_schedule();
        if let Some(idx) = replan {
            self.plan_interval(idx, now);
        }
    }

    /// Cross-cutting runtime invariants (property harness): pool
    /// accounting conserved, per-instance concurrency within the batch
    /// cap, allocator within capacity, down instances empty, and the
    /// buffer's O(1) lifecycle counters equal to their full phase scans
    /// (`RequestBuffer::check_invariants`) — asserted at every telemetry
    /// sample when enabled.
    fn assert_runtime_invariants(&self) {
        self.pool.check_invariants();
        self.buffer.check_invariants();
        for inst in &self.instances {
            assert!(
                inst.running.len() <= self.cfg.hw.max_batch,
                "instance {} over batch cap: {} > {}",
                inst.id.0,
                inst.running.len(),
                self.cfg.hw.max_batch
            );
            assert!(
                inst.alloc.used_blocks() <= inst.alloc.capacity_blocks(),
                "instance {} KV over-committed",
                inst.id.0
            );
            if !inst.up {
                assert!(
                    inst.running.is_empty() && inst.pending.is_empty(),
                    "down instance {} still holds requests",
                    inst.id.0
                );
            }
        }
    }

    // ------------------------------------------------------------------
    // Interval planning: decide SD budgets and the next boundary.
    // ------------------------------------------------------------------

    fn plan_interval(&mut self, idx: usize, now: SimTime) {
        let Some(t0) = self.profile.as_ref().map(|_| Instant::now()) else {
            self.plan_interval_inner(idx, now);
            return;
        };
        // Count only invocations that did planning work: the function is
        // called opportunistically after nearly every commit/arrival and
        // usually early-returns, which would dilute the per-call mean
        // into meaninglessness.
        let planned = self.plan_interval_inner(idx, now);
        if let Some(p) = self.profile.as_deref_mut() {
            if planned {
                p.plan_calls += 1;
                p.plan_ns += t0.elapsed().as_nanos() as u64;
            }
        }
    }

    /// Returns whether an interval-planning pass actually ran (false on
    /// the opportunistic early-outs).
    fn plan_interval_inner(&mut self, idx: usize, now: SimTime) -> bool {
        let inst = &self.instances[idx];
        if !inst.up || inst.interval.is_some() || inst.running.is_empty() {
            return false;
        }

        // --- SD decision ------------------------------------------------
        let batch = inst.running.len();
        let ids: Vec<RequestId> = inst.running.keys().copied().collect();
        let mut high = 0usize;
        let mut ctxs: Vec<(RequestId, SpecCtx, bool)> = Vec::with_capacity(batch);
        for id in &ids {
            let r = self.buffer.get(*id);
            let gp = self.group_progress[r.group().0 as usize];
            // Fresh references the group CST holds: finished siblings
            // plus concurrently-running ones (their prefixes are
            // aggregated). Streams surviving from previous iterations
            // travel separately in `warm_refs` — the acceptance model
            // discounts them by policy drift (RhymeRL history replay)
            // instead of counting them like same-policy siblings.
            let fresh = gp.finished + gp.running.saturating_sub(1);
            // Probes only get the high-priority SD budget while the
            // group is truly context-less — the same condition the
            // scheduler's probe-skip uses (finish signal or warm prior).
            let hp = r.is_probe && gp.finished == 0 && !gp.warm_ctx;
            if hp {
                high += 1;
            }
            // Multi-path drafting pays off in the low-concurrency tail.
            let top_k = if batch <= 8 { 4 } else { 1 };
            ctxs.push((
                *id,
                SpecCtx {
                    generated: r.generated,
                    group_refs: fresh,
                    warm_refs: gp.warm_refs,
                    drift: self.warm_drift,
                    top_k,
                },
                hp,
            ));
        }

        let kv_tokens = inst.alloc.used_tokens();
        let (gamma_h, gamma_l) = match self.spec.strategy {
            SdStrategy::None => (0, 0),
            SdStrategy::GroupedCst => {
                // MBA (paper Alg. 1) with the batch-mean β profile.
                let mean_ctx = SpecCtx {
                    generated: ctxs
                        .iter()
                        .map(|(_, c, _)| c.generated as u64)
                        .sum::<u64>() as u32
                        / batch as u32,
                    group_refs: ctxs
                        .iter()
                        .map(|(_, c, _)| c.group_refs)
                        .sum::<usize>()
                        / batch,
                    warm_refs: ctxs
                        .iter()
                        .map(|(_, c, _)| c.warm_refs)
                        .sum::<usize>()
                        / batch,
                    drift: self.warm_drift,
                    top_k: ctxs[0].1.top_k,
                };
                let beta =
                    self.spec.beta_profile(&mean_ctx, self.sys.gamma_max);
                let alpha = self.spec.alpha(&mean_ctx);
                let d = mba_allocate(
                    &self.cost,
                    &MbaInputs {
                        batch_high: high,
                        batch_low: batch - high,
                        beta,
                        gamma_max: self.sys.gamma_max,
                        lambda: self.sys.mba_lambda,
                        alpha,
                        kv_tokens,
                        draft_cost_per_gamma: SimTime::from_micros(2),
                    },
                );
                (d.gamma_high, d.gamma_low)
            }
            _ => {
                // Vanilla strategies with uniform adaptive γ (the paper
                // grants baselines adaptive draft lengths, §4.2.1).
                let mean_ctx = ctxs[0].1;
                let alpha = self.spec.alpha(&mean_ctx);
                let mut best = (0u32, self
                    .cost
                    .step_time(batch, kv_tokens, batch as u64)
                    .as_secs_f64());
                for g in 1..=self.spec.static_gamma() {
                    let t = self.cost.t_sd(
                        batch,
                        kv_tokens,
                        g,
                        alpha,
                        self.spec.draft_cost(batch, g),
                    );
                    if t < best.1 {
                        best = (g, t);
                    }
                }
                (best.0, best.0)
            }
        };

        // --- Bubble drafting (BubbleSpec, §PAPERS.md) --------------------
        // Near end-of-rollout, drained instances sit idle while the
        // stragglers finish. With the knob on, that spare capacity backs
        // extra draft generation for the still-busy instances: γ deepens
        // toward γ_max and the offloaded share of the draft cost leaves
        // the critical path. Only fires when idle peers exist AND no
        // request is waiting — otherwise idle capacity would be serving
        // real work, not bubbles. The fleet scan is gated on the knob,
        // so the default path pays one float compare.
        let bubble_boost = if self.sys.bubble_draft_frac > 0.0
            && self.buffer.n_waiting() == 0
        {
            let mut idle = 0usize;
            let mut working = 0usize;
            for inst in &self.instances {
                if !inst.up {
                    continue;
                }
                if inst.running.is_empty() && inst.pending.is_empty() {
                    idle += 1;
                } else {
                    working += 1;
                }
            }
            if idle > 0 && working > 0 {
                (self.sys.bubble_draft_frac * idle as f64 / working as f64)
                    .min(1.0)
            } else {
                0.0
            }
        } else {
            0.0
        };

        // --- Rates -------------------------------------------------------
        let inst = &mut self.instances[idx];
        let mut min_steps = u64::MAX;
        let mut bubble_rate_delta = 0.0f64;
        for (id, ctx, hp) in &ctxs {
            let base_gamma = if *hp { gamma_h } else { gamma_l };
            let gamma = self.spec.bubble_gamma(
                base_gamma,
                self.sys.gamma_max,
                bubble_boost,
            );
            let alpha = self.spec.alpha(ctx);
            let rate = if gamma == 0 {
                1.0
            } else {
                CostModel::expected_accept_len(gamma, alpha)
            };
            if gamma > base_gamma {
                // Expected extra accepted tokens per step from the
                // bubble-deepened draft budget.
                let base_rate = if base_gamma == 0 {
                    1.0
                } else {
                    CostModel::expected_accept_len(base_gamma, alpha)
                };
                bubble_rate_delta += rate - base_rate;
            }
            let r = self.buffer.get(*id);
            let budget =
                r.remaining_true().min(r.chunk_remaining).max(1);
            let rr = inst.running.get_mut(id).unwrap();
            rr.rate = rate;
            rr.gamma = gamma;
            rr.high_priority = *hp;
            rr.interval_budget = budget;
            let steps = ((budget as f64 - rr.frac) / rate).ceil() as u64;
            min_steps = min_steps.min(steps.max(1));
        }

        // --- KV headroom: preempt until one step fits --------------------
        // Worst-case token growth over one step is batch + Σrate (each
        // request carries < 1 fractional token). Block-rounding overshoot
        // is absorbed by `grow_upto` clamping at commit time.
        loop {
            let inst = &self.instances[idx];
            let b = inst.running.len() as u64;
            let total_rate: f64 =
                inst.running.values().map(|r| r.rate).sum();
            let need = b + total_rate.ceil() as u64;
            if inst.alloc.free_tokens() >= need || inst.running.len() <= 1 {
                break;
            }
            let running: Vec<(RequestId, SimTime)> = inst
                .running
                .iter()
                .map(|(id, r)| (*id, r.started_at))
                .collect();
            let victim = self
                .scheduler
                .preempt_victim(&running, &self.buffer)
                .expect("no preemption victim");
            self.evict(idx, victim, now, true);
            self.schedule_dirty = true;
        }
        let inst = &mut self.instances[idx];
        if inst.running.is_empty() {
            // Real planning work happened (rates + preemption drained the
            // batch), even though no interval was installed.
            return true;
        }
        let batch = inst.running.len();
        let mut positions = 0u64;
        let mut max_gamma = 0u32;
        let mut total_rate = 0.0f64;
        for rr in inst.running.values() {
            positions += rr.gamma as u64 + 1;
            max_gamma = max_gamma.max(rr.gamma);
            total_rate += rr.rate;
        }
        let kv_tokens = inst.alloc.used_tokens();

        // KV boundary: after n steps total token growth is at most
        // batch + n·Σrate; stop the interval before free runs out.
        let free = inst.alloc.free_tokens();
        let kv_steps = ((free.saturating_sub(batch as u64)) as f64
            / total_rate)
            .floor() as u64;
        let n = min_steps.min(kv_steps.max(1)).clamp(1, 256);

        // Draft cost scales with the *mean* draft length over the batch
        // (total draft tokens), not the max.
        let mean_gamma = ((positions.saturating_sub(batch as u64)) as f64
            / batch as f64)
            .round() as u32;
        let _ = max_gamma;
        // The bubble-offloaded share of draft generation runs on idle
        // instances, so only the remainder stays on this instance's
        // critical path (inert at boost 0: `bubble_draft_cost` is then
        // exactly `draft_cost`).
        let full_draft = self.spec.draft_cost(batch, mean_gamma);
        let paid_draft =
            self.spec.bubble_draft_cost(batch, mean_gamma, bubble_boost);
        let step_time =
            self.cost.step_time(batch, kv_tokens, positions) + paid_draft;
        // Record this interval's bubble terms; commits scale them by the
        // steps actually run. Written unconditionally so an interval
        // planned without a bubble zeroes any stale entry.
        self.bubble_interval[idx] = BubbleStep {
            draft_secs: (full_draft.as_secs_f64()
                - paid_draft.as_secs_f64())
            .max(0.0),
            rate_delta: bubble_rate_delta,
        };
        // Straggler model: a slowed instance pays `slow_factor`× the
        // modeled step time until it recovers.
        let step_us = ((step_time.as_micros().max(1) as f64)
            * inst.slow_factor)
            .ceil() as u64;
        let iv = Interval {
            start: now,
            step_us: step_us.max(1),
            steps: n,
        };
        let end = iv.end();
        inst.set_interval(iv);
        let epoch = inst.epoch;
        self.queue.schedule_at(
            end,
            Event::Wake {
                instance: InstanceId(idx as u32),
                epoch,
            },
        );
        true
    }

    /// Remove a request from an instance. `preempted`: true for OOM
    /// eviction (vs. voluntary chunk-end parking).
    fn evict(
        &mut self,
        idx: usize,
        id: RequestId,
        now: SimTime,
        preempted: bool,
    ) {
        let inst = &mut self.instances[idx];
        inst.running.remove(&id).expect("evicting non-running request");
        inst.epoch += 1;
        let kv = inst.alloc.release(id);
        let r = self.buffer.get_mut(id);
        if self.scheduler.uses_global_pool() {
            // Park in the Mooncake pool: resume is a cheap fetch.
            let bytes = kv * self.cfg.hw.kv_bytes_per_token;
            self.pool.store(id, bytes);
            r.kv_location = KvLocation::Pool;
            r.needs_reprefill = false;
        } else {
            // Conventional preemption: KV dropped, re-prefill later.
            r.kv_location = KvLocation::Nowhere;
            r.kv_tokens = 0;
            r.needs_reprefill = true;
        }
        if preempted {
            r.preemptions += 1;
            self.metrics.preemptions += 1;
        }
        self.buffer.mark_waiting(id);
        let group = self.buffer.get(id).group();
        let gp = &mut self.group_progress[group.0 as usize];
        gp.running = gp.running.saturating_sub(1);
        // Both re-queue paths — voluntary chunk-end parking AND
        // preemption — report the request's in-flight progress to the
        // policy, so a migrated long request can't be demoted below its
        // demonstrated length by a stale estimate.
        let r = self.buffer.get(id).clone();
        self.scheduler.on_chunk_end(&r);
        self.emit_event(RolloutEvent::ChunkEnd {
            req: id,
            instance: InstanceId(idx as u32),
            preempted,
            now,
        });
    }

    // ------------------------------------------------------------------
    // Commit handling: apply token gains, detect completions/chunk ends.
    // ------------------------------------------------------------------

    fn commit_and_handle(&mut self, idx: usize, now: SimTime) {
        let Some(t0) = self.profile.as_ref().map(|_| Instant::now()) else {
            self.commit_and_handle_inner(idx, now);
            return;
        };
        // Only commits that applied gains count toward the breakdown
        // (see `plan_interval` — same dilution concern).
        let committed = self.commit_and_handle_inner(idx, now);
        if let Some(p) = self.profile.as_deref_mut() {
            if committed {
                p.commit_calls += 1;
                p.commit_ns += t0.elapsed().as_nanos() as u64;
            }
        }
    }

    /// Returns whether the commit applied any gains (false when no
    /// interval was in flight).
    fn commit_and_handle_inner(&mut self, idx: usize, now: SimTime) -> bool {
        let commit = self.instances[idx].commit_until(now);
        if commit.gained.is_empty() {
            return false;
        }
        // Bubble drafting: charge the interval's per-step offload/uplift
        // terms for the steps that actually ran (intervals close early on
        // arrivals and faults, so plan-time totals would over-count).
        let bs = self.bubble_interval[idx];
        if bs.draft_secs > 0.0 || bs.rate_delta > 0.0 {
            self.bubble_draft_secs += bs.draft_secs * commit.steps;
            self.bubble_accept_est += bs.rate_delta * commit.steps;
        }
        let mut completed = Vec::new();
        let mut chunk_ended = Vec::new();
        let mut granted_total = 0u64;
        for (id, gain) in &commit.gained {
            let inst = &mut self.instances[idx];
            // τ accounting over SD-active request-steps only (the paper's
            // acceptance-length metric is per verify step).
            if let Some(rr) = inst.running.get(id) {
                if rr.gamma > 0 {
                    self.accept_steps += commit.steps;
                    self.accept_len_weighted += *gain as f64;
                }
            }
            // Clamp to KV capacity: tokens beyond the granted amount are
            // lost (the step stalled at the memory wall; the fluid model
            // charges the time but not the progress).
            let granted = if *gain > 0 {
                inst.alloc.grow_upto(*id, *gain as u64) as u32
            } else {
                0
            };
            let r = self.buffer.get_mut(*id);
            r.generated += granted;
            r.kv_tokens += granted as u64;
            debug_assert!(r.generated <= r.spec.gen_len);
            r.chunk_remaining = r.chunk_remaining.saturating_sub(granted);
            self.metrics.tokens_generated += granted as u64;
            granted_total += granted as u64;
            if r.generated >= r.spec.gen_len {
                completed.push(*id);
            } else if r.chunk_remaining == 0 {
                chunk_ended.push(*id);
            }
        }
        self.metrics.spec_accepted_tokens +=
            commit.accepted_tokens.round() as u64;
        self.emit_event(RolloutEvent::Step {
            instance: InstanceId(idx as u32),
            steps: commit.steps.round() as u64,
            tokens: granted_total,
            now,
        });

        for id in completed {
            self.finish_request(idx, id, now);
        }
        for id in chunk_ended {
            let r = self.buffer.get(id);
            debug_assert!(!r.is_finished());
            // `evict` notifies the scheduler's on_chunk_end hook.
            self.evict(idx, id, now, false);
            self.schedule_dirty = true;
        }
        true
    }

    fn finish_request(&mut self, idx: usize, id: RequestId, now: SimTime) {
        let inst = &mut self.instances[idx];
        inst.running.remove(&id).expect("finishing non-running request");
        inst.epoch += 1;
        inst.alloc.release(id);
        self.pool.remove(id);
        let r = self.buffer.get_mut(id);
        r.finished_at = Some(now);
        r.kv_location = KvLocation::Nowhere;
        let first = r.first_scheduled.unwrap_or(now);
        let gen_len = r.generated;
        let group = r.group();
        self.buffer.mark_finished(id);
        self.n_completed += 1;
        self.metrics.completions.push(Completion {
            id,
            finished_at: now,
            first_scheduled_at: first,
            gen_len,
            policy_version: self.policy_version,
        });
        let gp = &mut self.group_progress[group.0 as usize];
        gp.finished += 1;
        gp.running = gp.running.saturating_sub(1);
        let r = self.buffer.get(id).clone();
        self.scheduler.on_finished(&r);
        self.schedule_dirty = true;
        self.emit_event(RolloutEvent::Finished {
            req: id,
            gen_len,
            now,
        });
    }

    /// Narrate one lifecycle event to the attached observers, counting
    /// emission wall time when profiling is on.
    fn emit_event(&mut self, ev: RolloutEvent) {
        if self.profile.is_some() {
            let t0 = Instant::now();
            self.observers.emit(ev);
            if let Some(p) = self.profile.as_deref_mut() {
                p.emit_events += 1;
                p.emit_ns += t0.elapsed().as_nanos() as u64;
            }
        } else {
            self.observers.emit(ev);
        }
    }

    // ------------------------------------------------------------------
    // Scheduling + arrivals.
    // ------------------------------------------------------------------

    fn try_schedule(&mut self) {
        if !self.schedule_dirty || self.buffer.n_waiting() == 0 {
            return;
        }
        self.schedule_dirty = false;
        let now = self.queue.now();
        // Down instances are invisible to the policy: they receive no
        // assignments and contribute no capacity. Views and assignments
        // live in reusable scratch buffers — a steady-state pass
        // allocates nothing. (Scratch fill is O(instances), which is
        // o(waiting); the pass itself is incremental inside the policy.)
        let mut views = std::mem::take(&mut self.views_scratch);
        views.clear();
        views.extend(self.instances.iter().filter(|inst| inst.up).map(
            |inst| InstanceView {
                id: inst.id,
                free_kv_tokens: inst
                    .admission_headroom(self.sys.kv_target_util),
                capacity_tokens: inst.capacity_tokens,
                running: inst.running.len() + inst.pending.len(),
                max_batch: self.cfg.hw.max_batch,
            },
        ));
        if views.is_empty() {
            // Fully downed fleet; a recover/scale-up may revive it.
            self.views_scratch = views;
            return;
        }
        let mut assignments = std::mem::take(&mut self.assign_scratch);
        assignments.clear();
        {
            let t0 = self.profile.as_ref().map(|_| Instant::now());
            let ctx = SchedCtx {
                now,
                instances: &views,
                buffer: &self.buffer,
            };
            self.scheduler.schedule(&ctx, &mut assignments);
            if let (Some(p), Some(t0)) = (self.profile.as_deref_mut(), t0) {
                p.sched_passes += 1;
                p.sched_ns += t0.elapsed().as_nanos() as u64;
                p.waiting_sum += self.buffer.n_waiting() as u64;
                p.assignments += assignments.len() as u64;
            }
        }
        for a in assignments.iter().copied() {
            let idx = a.instance.0 as usize;
            let r = self.buffer.get(a.req);
            debug_assert!(matches!(r.phase, Phase::Waiting));
            // Validate the *full* lease the policy granted: whole-request
            // policies (veRL/StreamRL) deliberately lease beyond the
            // divided-rollout chunk size, and clamping their demand here
            // would second-guess the optimistic-admission behavior under
            // study. (A historical `min(chunk_size.max(chunk))` clamp
            // always evaluated to `a.chunk` — it was dead by
            // construction and is spelled plainly now.)
            let demand = r.kv_demand(a.chunk);
            // Defense in depth: re-validate against live headroom and
            // liveness (a buggy policy cannot place onto a down fleet).
            if !self.instances[idx].up
                || self.instances[idx].admission_headroom(1.0) < demand
            {
                self.schedule_dirty = true;
                // Tell the policy its assignment never materialized, so
                // incremental candidate indexes re-stamp the request —
                // it is still waiting and must be schedulable next pass.
                self.scheduler.on_requeued(self.buffer.get(a.req));
                continue;
            }
            let chunk = a.chunk.min(
                self.cfg.max_gen_len, // lease can't exceed the cap
            );
            // Transfer / prefill delay before the request joins the batch.
            let mut migrated = false;
            let r = self.buffer.get_mut(a.req);
            let delay = if r.needs_reprefill {
                let tokens = r.spec.prompt_len as u64 + r.generated as u64;
                if r.generated > 0 {
                    self.metrics.re_prefill_tokens += tokens;
                }
                r.kv_tokens = tokens; // will materialize on arrival
                self.cost.prefill_time(tokens)
            } else if r.kv_location == KvLocation::Pool {
                let t = self
                    .pool
                    .fetch(a.req)
                    .expect("pool lost a parked request");
                let moved =
                    self.last_instance[a.req.0 as usize] != Some(a.instance);
                if moved {
                    migrated = true;
                    r.migrations += 1;
                    self.metrics.migrations += 1;
                    self.metrics.migrated_bytes +=
                        r.kv_tokens * self.cfg.hw.kv_bytes_per_token;
                }
                t
            } else {
                SimTime::from_micros(100)
            };
            r.chunk_remaining = chunk;
            r.chunks_run += 1;
            r.kv_location = KvLocation::Instance(a.instance);
            if r.first_scheduled.is_none() {
                r.first_scheduled = Some(now);
            }
            let base_kv = r.kv_tokens;
            let chunk_seq = r.chunks_run;
            // Waiting → Running through the buffer, which owns the O(1)
            // lifecycle counters the event loop's done() check reads.
            self.buffer.mark_running(a.req, a.instance);
            self.instances[idx].pending.insert(a.req, base_kv + chunk as u64);
            self.last_instance[a.req.0 as usize] = Some(a.instance);
            self.queue.schedule_at(
                now + delay,
                Event::Arrive {
                    req: a.req,
                    chunk_seq,
                },
            );
            self.emit_event(RolloutEvent::Scheduled {
                req: a.req,
                instance: a.instance,
                now,
            });
            if migrated {
                self.emit_event(RolloutEvent::Migration {
                    req: a.req,
                    to: a.instance,
                    now,
                });
            }
        }
        self.views_scratch = views;
        self.assign_scratch = assignments;
    }

    fn handle_arrival(&mut self, id: RequestId, chunk_seq: u32, now: SimTime) {
        let r = self.buffer.get(id);
        let Phase::Running(inst_id) = r.phase else {
            // Lease revoked in flight: drained by a fault, aborted, or
            // already parked again — the arrival is stale.
            return;
        };
        if r.chunks_run != chunk_seq {
            // The request was drained by a fault and re-scheduled before
            // this (older lease's) transfer completed.
            return;
        }
        let idx = inst_id.0 as usize;
        debug_assert!(
            self.instances[idx].up,
            "arrival on a down instance survived the drain guards"
        );
        // Close the in-flight interval before batch composition changes.
        self.commit_and_handle(idx, now);

        let inst = &mut self.instances[idx];
        inst.pending.remove(&id);
        let r = self.buffer.get_mut(id);
        let base = r.kv_tokens.max(r.spec.prompt_len as u64);
        r.kv_tokens = base;
        if !self.instances[idx].alloc.grow(id, base) {
            // Capacity was consumed while in flight: bounce back. The
            // phase write happens inside mark_waiting, which keeps the
            // O(1) running counter honest.
            let r = self.buffer.get_mut(id);
            r.kv_location = if self.scheduler.uses_global_pool()
                && !r.needs_reprefill
            {
                let bytes = r.kv_tokens * self.cfg.hw.kv_bytes_per_token;
                self.pool.store(id, bytes);
                KvLocation::Pool
            } else {
                r.kv_tokens = 0;
                r.needs_reprefill = true;
                KvLocation::Nowhere
            };
            self.buffer.mark_waiting(id);
            // A bounced admission re-enters the waiting set with no
            // progress change: incremental policies re-index it here.
            self.scheduler.on_requeued(self.buffer.get(id));
            self.schedule_dirty = true;
            self.try_schedule();
            // The commit above closed the running interval — re-plan so
            // the resident batch keeps generating.
            self.plan_interval(idx, now);
            return;
        }
        let r = self.buffer.get_mut(id);
        r.needs_reprefill = false;
        let inst = &mut self.instances[idx];
        inst.running.insert(
            id,
            RunningReq {
                rate: 1.0,
                gamma: 0,
                frac: 0.0,
                interval_budget: 0,
                high_priority: false,
                started_at: now,
            },
        );
        inst.epoch += 1;
        let group = self.buffer.get(id).group();
        self.group_progress[group.0 as usize].running += 1;
        // Fault recovery closes HERE, not at assignment time: only a
        // materialized placement counts (an in-flight admission can
        // still bounce on the live-headroom re-check above, in which
        // case the request stays marked drained and its real, longer
        // recovery is measured at the next successful arrival).
        if let Some(t0) = self.drained_by_fault[id.0 as usize].take() {
            self.metrics.fault_recovery_time += now.saturating_sub(t0);
            self.metrics.fault_recovered += 1;
            self.emit_event(RolloutEvent::Rebalanced {
                req: id,
                to: inst_id,
                now,
            });
        }
        self.plan_interval(idx, now);
    }

    fn record_sample(&mut self, now: SimTime) {
        // Telemetry is *sampled* at the base cadence but *recorded* at a
        // stride that doubles whenever the series would outgrow the cap:
        // long runs keep O(max_load_samples) memory instead of one
        // sample per instance per 10 virtual seconds forever. Sampling
        // cadence (and hence the event sequence) never changes, and no
        // report metric reads `load_samples`, so decimation is invisible
        // to report JSON.
        let tick = self.sample_ticks;
        self.sample_ticks += 1;
        if tick % self.sample_stride != 0 {
            return;
        }
        self.load_ticks
            .push((tick, self.metrics.load_samples.len() as u32));
        for inst in &self.instances {
            self.metrics.load_samples.push(LoadSample {
                t: now,
                instance: inst.id,
                kv_utilization: inst.kv_utilization(),
                running: inst.running.len(),
            });
        }
        while self.metrics.load_samples.len() > self.max_load_samples
            && self.load_ticks.len() > 1
        {
            self.decimate_samples();
        }
    }

    /// Halve the recorded telemetry: keep only ticks divisible by the
    /// doubled stride (tick 0 always survives, so the series keeps its
    /// anchor; the newest kept ticks align with all future recordings).
    /// Deterministic — a pure function of the virtual-time tick history.
    fn decimate_samples(&mut self) {
        self.sample_stride *= 2;
        let old_samples = std::mem::take(&mut self.metrics.load_samples);
        let old_ticks = std::mem::take(&mut self.load_ticks);
        for (i, &(tick, start)) in old_ticks.iter().enumerate() {
            if tick % self.sample_stride != 0 {
                continue;
            }
            let end = old_ticks
                .get(i + 1)
                .map(|&(_, s)| s as usize)
                .unwrap_or(old_samples.len());
            self.load_ticks
                .push((tick, self.metrics.load_samples.len() as u32));
            self.metrics
                .load_samples
                .extend_from_slice(&old_samples[start as usize..end]);
        }
    }

    /// Mean acceptance length over the whole run (τ, Figure 11).
    pub fn mean_acceptance(&self) -> f64 {
        if self.accept_steps == 0.0 {
            1.0
        } else {
            self.accept_len_weighted / self.accept_steps
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::scheduler::{ContextMode, SeerScheduler, VerlScheduler};

    fn quick_run(
        preset: TaskPreset,
        sched: Box<dyn Scheduler>,
        sd: SdStrategy,
    ) -> RolloutOutcome {
        let cfg = preset.workload_for_test();
        let sys = SystemConfig {
            chunk_size: 128,
            ..Default::default()
        };
        let w = crate::workload::generate_iteration(&cfg, 42);
        ClusterSim::new(cfg, sys, w.groups, sched, sd)
            .sample_interval(SimTime::from_secs(2))
            .run()
    }

    #[test]
    fn verl_completes_all_requests() {
        let out = quick_run(
            TaskPreset::Moonlight,
            Box::new(VerlScheduler::new()),
            SdStrategy::None,
        );
        let cfg = TaskPreset::Moonlight.workload_for_test();
        assert_eq!(out.metrics.completions.len(), cfg.reqs_per_iter);
        assert!(out.metrics.makespan > SimTime::ZERO);
        assert!(out.metrics.tokens_generated > 0);
        out.buffer.check_invariants();
    }

    #[test]
    fn seer_completes_all_requests() {
        let out = quick_run(
            TaskPreset::Moonlight,
            Box::new(SeerScheduler::new(ContextMode::Learned)),
            SdStrategy::GroupedCst,
        );
        let cfg = TaskPreset::Moonlight.workload_for_test();
        assert_eq!(out.metrics.completions.len(), cfg.reqs_per_iter);
        out.buffer.check_invariants();
    }

    #[test]
    fn generated_tokens_match_workload() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = crate::workload::generate_iteration(&cfg, 7);
        let expected = w.total_gen_tokens();
        let sim = ClusterSim::new(
            cfg,
            SystemConfig {
                chunk_size: 128,
                ..Default::default()
            },
            w.groups,
            Box::new(SeerScheduler::new(ContextMode::Learned)),
            SdStrategy::None,
        );
        let out = sim.run();
        assert_eq!(out.metrics.tokens_generated, expected);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = quick_run(
            TaskPreset::Qwen2Vl72b,
            Box::new(SeerScheduler::new(ContextMode::Learned)),
            SdStrategy::GroupedCst,
        );
        let b = quick_run(
            TaskPreset::Qwen2Vl72b,
            Box::new(SeerScheduler::new(ContextMode::Learned)),
            SdStrategy::GroupedCst,
        );
        assert_eq!(a.metrics.makespan, b.metrics.makespan);
        assert_eq!(a.metrics.tokens_generated, b.metrics.tokens_generated);
        assert_eq!(a.metrics.preemptions, b.metrics.preemptions);
    }

    #[test]
    fn seer_beats_verl_on_memory_constrained_task() {
        let verl = quick_run(
            TaskPreset::Qwen2Vl72b,
            Box::new(VerlScheduler::new()),
            SdStrategy::None,
        );
        let seer = quick_run(
            TaskPreset::Qwen2Vl72b,
            Box::new(SeerScheduler::new(ContextMode::Learned)),
            SdStrategy::None,
        );
        assert!(
            seer.metrics.makespan < verl.metrics.makespan,
            "seer {:?} vs verl {:?}",
            seer.metrics.makespan,
            verl.metrics.makespan
        );
    }

    #[test]
    fn partial_rollout_stops_early() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = crate::workload::generate_iteration(&cfg, 3);
        let target = cfg.reqs_per_iter / 2;
        let out = ClusterSim::new(
            cfg,
            SystemConfig::default(),
            w.groups,
            Box::new(VerlScheduler::new()),
            SdStrategy::None,
        )
        .stop_after(target)
        .run();
        assert!(out.metrics.completions.len() >= target);
        assert!(out.metrics.completions.len() < out.buffer.len());
    }

    #[test]
    fn instance_down_drains_requeues_and_still_completes() {
        // t=0 faults fire before any completion at every scale.
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = crate::workload::generate_iteration(&cfg, 42);
        let plan = crate::sim::faults::FaultPlan::new().at(
            0.0,
            crate::sim::faults::FaultEvent::InstanceDown {
                instance: InstanceId(1),
            },
        );
        let out = ClusterSim::new(
            cfg.clone(),
            SystemConfig {
                chunk_size: 128,
                ..Default::default()
            },
            w.groups,
            Box::new(SeerScheduler::new(ContextMode::Learned)),
            SdStrategy::None,
        )
        .with_faults(plan)
        .with_invariant_checks()
        .run();
        assert_eq!(out.metrics.instances_lost, 1);
        assert_eq!(out.metrics.completions.len(), cfg.reqs_per_iter);
        // Everything the initial scheduling cycle had placed on the
        // crashed instance was drained and later recovered.
        assert_eq!(out.metrics.fault_requeued, out.metrics.fault_recovered);
        out.buffer.check_invariants();
    }

    #[test]
    fn scale_up_instance_receives_work_under_verl() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = crate::workload::generate_iteration(&cfg, 42);
        let plan = crate::sim::faults::FaultPlan::new()
            .at(0.0, crate::sim::faults::FaultEvent::ScaleUp { n: 1 });
        let out = ClusterSim::new(
            cfg.clone(),
            SystemConfig::default(),
            w.groups,
            Box::new(VerlScheduler::new()),
            SdStrategy::None,
        )
        .with_faults(plan)
        .run();
        assert_eq!(out.metrics.instances_added, 1);
        assert_eq!(out.metrics.completions.len(), cfg.reqs_per_iter);
        assert_eq!(out.metrics.busy_time.len(), cfg.n_instances + 1);
        assert!(
            out.metrics.busy_time[cfg.n_instances] > SimTime::ZERO,
            "scale-up instance never did any work"
        );
    }

    /// The stepping surface is the single-shot path: `start` +
    /// `step_until` segments + `finish` must reproduce `run` exactly,
    /// whatever the segment boundaries (the stream/pipeline layer relies
    /// on this to keep async-lag-0 byte-identical to sync).
    #[test]
    fn stepped_run_matches_single_shot() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let build = || {
            let w = crate::workload::generate_iteration(&cfg, 42);
            ClusterSim::new(
                cfg.clone(),
                SystemConfig {
                    chunk_size: 128,
                    ..Default::default()
                },
                w.groups,
                Box::new(SeerScheduler::new(ContextMode::Learned)),
                SdStrategy::GroupedCst,
            )
        };
        let single = build().run();
        let mut sim = build();
        sim.start();
        let mut deadline = SimTime::ZERO;
        while !sim.step_until(deadline) {
            deadline += SimTime::from_secs(3);
        }
        let stepped = sim.finish();
        assert_eq!(single.metrics.makespan, stepped.metrics.makespan);
        assert_eq!(
            single.metrics.tokens_generated,
            stepped.metrics.tokens_generated
        );
        assert_eq!(single.metrics.preemptions, stepped.metrics.preemptions);
        assert_eq!(single.metrics.engine_steps, stepped.metrics.engine_steps);
        assert_eq!(single.metrics.busy_time, stepped.metrics.busy_time);
        let fin = |o: &RolloutOutcome| {
            o.metrics
                .completions
                .iter()
                .map(|c| (c.id.0, c.finished_at, c.gen_len, c.policy_version))
                .collect::<Vec<_>>()
        };
        assert_eq!(fin(&single), fin(&stepped));
    }

    /// Live-interval accounting (utilization bugfix): always-live fleets
    /// report `live_time == makespan` per instance, while a scale-up
    /// instance is only live from its join — so a busy late joiner no
    /// longer deflates `mean_utilization`.
    #[test]
    fn live_time_excludes_pre_join_intervals() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let run_with = |plan: crate::sim::faults::FaultPlan| {
            let w = crate::workload::generate_iteration(&cfg, 42);
            ClusterSim::new(
                cfg.clone(),
                SystemConfig::default(),
                w.groups,
                Box::new(VerlScheduler::new()),
                SdStrategy::None,
            )
            .with_faults(plan)
            .run()
        };
        let clean = run_with(crate::sim::faults::FaultPlan::new());
        for t in &clean.metrics.live_time {
            assert_eq!(*t, clean.metrics.makespan);
        }
        let horizon = clean.metrics.makespan.as_secs_f64();
        let out = run_with(
            crate::sim::faults::FaultPlan::new()
                .at(0.3 * horizon, crate::sim::faults::FaultEvent::ScaleUp { n: 1 }),
        );
        let m = &out.metrics;
        assert_eq!(m.instances_added, 1);
        let joined = m.live_time[cfg.n_instances];
        assert!(joined > SimTime::ZERO, "late joiner never went live");
        assert!(
            joined < m.makespan,
            "live interval must start at the join, not t=0"
        );
        assert!(m.busy_time[cfg.n_instances] > SimTime::ZERO);
        assert!(m.busy_time[cfg.n_instances] <= joined);
        // The old formula divided the joiner's busy time by the full
        // makespan; the live-interval denominator can only raise it.
        let naive: f64 = m
            .busy_time
            .iter()
            .map(|t| t.as_secs_f64())
            .sum::<f64>()
            / (m.makespan.as_secs_f64() * m.busy_time.len() as f64);
        assert!(m.mean_utilization() > naive);
    }

    #[test]
    fn abort_terminates_without_completion() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = crate::workload::generate_iteration(&cfg, 42);
        let plan = crate::sim::faults::FaultPlan::new().at(
            0.0,
            crate::sim::faults::FaultEvent::RequestAbort {
                req: crate::workload::RequestId(3),
            },
        );
        let out = ClusterSim::new(
            cfg.clone(),
            SystemConfig::default(),
            w.groups,
            Box::new(VerlScheduler::new()),
            SdStrategy::None,
        )
        .with_faults(plan)
        .run();
        assert_eq!(out.metrics.aborted, 1);
        assert_eq!(out.metrics.completions.len(), cfg.reqs_per_iter - 1);
        assert!(out.buffer.get(crate::workload::RequestId(3)).aborted);
        out.buffer.check_invariants();
    }

    /// Regression (review finding): with a pinned policy, downing the
    /// whole fleet and then recovering one instance used to starve
    /// forever — the loss hook had no live instance to re-pin onto, and
    /// recovery fired no hook, so every group stayed pinned to a dead
    /// instance while the liveness assert saw a healthy fleet. Recovery
    /// now fires `on_instances_added`, which re-homes the waiting work.
    #[test]
    fn recovery_after_full_outage_unsticks_pinned_policies() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let w = crate::workload::generate_iteration(&cfg, 42);
        let plan = crate::sim::faults::FaultPlan::new()
            .at(
                0.0,
                crate::sim::faults::FaultEvent::InstanceDown {
                    instance: InstanceId(1),
                },
            )
            .at(
                0.0,
                crate::sim::faults::FaultEvent::InstanceDown {
                    instance: InstanceId(0),
                },
            )
            .at(
                0.0,
                crate::sim::faults::FaultEvent::InstanceRecover {
                    instance: InstanceId(1),
                },
            );
        let out = ClusterSim::new(
            cfg.clone(),
            SystemConfig::default(),
            w.groups,
            Box::new(VerlScheduler::new()),
            SdStrategy::None,
        )
        .with_faults(plan)
        .run();
        assert_eq!(out.metrics.instances_lost, 2);
        assert_eq!(out.metrics.completions.len(), cfg.reqs_per_iter);
        out.buffer.check_invariants();
    }

    #[test]
    fn slowdown_stretches_the_rollout() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let run_with = |plan: crate::sim::faults::FaultPlan| {
            let w = crate::workload::generate_iteration(&cfg, 42);
            ClusterSim::new(
                cfg.clone(),
                SystemConfig::default(),
                w.groups,
                Box::new(VerlScheduler::new()),
                SdStrategy::None,
            )
            .with_faults(plan)
            .run()
        };
        let clean = run_with(crate::sim::faults::FaultPlan::new());
        let slow = run_with(crate::sim::faults::FaultPlan::new().at(
            0.0,
            crate::sim::faults::FaultEvent::InstanceSlowdown {
                instance: InstanceId(0),
                factor: 3.0,
            },
        ));
        assert!(
            slow.metrics.makespan > clean.metrics.makespan,
            "3x straggler did not stretch the rollout: {:?} vs {:?}",
            slow.metrics.makespan,
            clean.metrics.makespan
        );
        assert_eq!(slow.metrics.completions.len(), cfg.reqs_per_iter);
    }

    /// ISSUE 5 satellite: long runs must not accumulate unbounded
    /// telemetry. With a tiny cap the recorded series stays bounded via
    /// stride-doubling decimation, while every derived report metric is
    /// bit-identical to the uncapped run (no report metric reads
    /// `load_samples`) and the kept samples are a subset of the full
    /// series.
    #[test]
    fn telemetry_decimation_bounds_memory_and_preserves_metrics() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let run = |cap: Option<usize>| {
            let w = crate::workload::generate_iteration(&cfg, 11);
            let mut sim = ClusterSim::new(
                cfg.clone(),
                SystemConfig::default(),
                w.groups,
                Box::new(VerlScheduler::new()),
                SdStrategy::None,
            )
            .sample_interval(SimTime::from_millis(50));
            if let Some(c) = cap {
                sim = sim.max_load_samples(c);
            }
            sim.run()
        };
        let full = run(None);
        let bounded = run(Some(64));
        assert!(
            full.metrics.load_samples.len() > 64,
            "run too short to exercise decimation"
        );
        assert!(bounded.metrics.load_samples.len() <= 64);
        assert!(!bounded.metrics.load_samples.is_empty());
        // Derived report metrics are untouched by decimation.
        assert_eq!(bounded.metrics.makespan, full.metrics.makespan);
        assert_eq!(
            bounded.metrics.tokens_generated,
            full.metrics.tokens_generated
        );
        assert_eq!(bounded.metrics.preemptions, full.metrics.preemptions);
        assert_eq!(
            bounded.metrics.completions.len(),
            full.metrics.completions.len()
        );
        // The kept series is a subset of the full one, in order.
        let key = |s: &crate::metrics::LoadSample| (s.t, s.instance.0);
        let full_keys: Vec<_> =
            full.metrics.load_samples.iter().map(key).collect();
        let mut cursor = 0usize;
        for s in &bounded.metrics.load_samples {
            let k = key(s);
            let pos = full_keys[cursor..]
                .iter()
                .position(|fk| *fk == k)
                .expect("decimated sample missing from full series");
            cursor += pos + 1;
        }
    }

    /// `--profile` collects wall-time attribution only: the emitted
    /// virtual-time results must be bit-identical with it on.
    #[test]
    fn profiling_does_not_change_results() {
        let cfg = TaskPreset::Moonlight.workload_for_test();
        let run = |profiled: bool| {
            let w = crate::workload::generate_iteration(&cfg, 9);
            let mut sim = ClusterSim::new(
                cfg.clone(),
                SystemConfig::default(),
                w.groups,
                Box::new(SeerScheduler::new(ContextMode::Learned)),
                SdStrategy::GroupedCst,
            );
            if profiled {
                sim = sim.with_profiling();
            }
            sim.run()
        };
        let plain = run(false);
        let profiled = run(true);
        assert_eq!(plain.metrics.makespan, profiled.metrics.makespan);
        assert_eq!(
            plain.metrics.tokens_generated,
            profiled.metrics.tokens_generated
        );
        let fa: Vec<_> = plain
            .metrics
            .completions
            .iter()
            .map(|c| (c.id, c.finished_at))
            .collect();
        let fb: Vec<_> = profiled
            .metrics
            .completions
            .iter()
            .map(|c| (c.id, c.finished_at))
            .collect();
        assert_eq!(fa, fb);
    }

    #[test]
    fn verl_preempts_under_pressure_seer_does_not() {
        let verl = quick_run(
            TaskPreset::Qwen2Vl72b,
            Box::new(VerlScheduler::new()),
            SdStrategy::None,
        );
        let seer = quick_run(
            TaskPreset::Qwen2Vl72b,
            Box::new(SeerScheduler::new(ContextMode::Learned)),
            SdStrategy::None,
        );
        assert!(
            verl.metrics.preemptions > 0,
            "baseline should preempt on a memory-constrained task"
        );
        assert!(
            seer.metrics.preemptions * 10 <= verl.metrics.preemptions.max(10),
            "seer {} vs verl {}",
            seer.metrics.preemptions,
            verl.metrics.preemptions
        );
    }
}
