//! One simulated inference instance: continuous batching over a paged KV
//! allocator, advanced in *macro-intervals* — between two scheduling
//! boundaries every running request generates tokens at its expected
//! per-step rate (1 + expected accepted draft tokens), so the simulator
//! pays one event per boundary instead of one per token. The cluster
//! driver (`cluster.rs`) plans intervals, commits progress and handles
//! completions/chunk-expiries/preemptions.

use crate::kvcache::PagedAllocator;
use crate::sim::clock::SimTime;
use crate::util::sortedmap::SortedVecMap;
use crate::workload::{InstanceId, RequestId};

/// Per-running-request state within an instance.
#[derive(Debug, Clone)]
pub struct RunningReq {
    /// Expected tokens per engine step in the current interval
    /// (1.0 for plain decode; (1-α^{γ+1})/(1-α) with SD).
    pub rate: f64,
    /// Draft length assigned for this interval.
    pub gamma: u32,
    /// Fractional token progress carried across intervals.
    pub frac: f64,
    /// Max whole tokens this request may gain in the current interval
    /// (min of chunk lease remainder and true remaining length).
    pub interval_budget: u32,
    /// Probe / high-priority flag at interval planning time.
    pub high_priority: bool,
    pub started_at: SimTime,
}

/// An in-flight macro-interval.
#[derive(Debug, Clone, Copy)]
pub struct Interval {
    pub start: SimTime,
    /// Engine step time in microseconds (incl. draft cost amortized).
    pub step_us: u64,
    /// Planned number of engine steps.
    pub steps: u64,
}

impl Interval {
    pub fn end(&self) -> SimTime {
        SimTime::from_micros(self.start.as_micros() + self.step_us * self.steps)
    }
}

/// Result of committing an interval (possibly partially).
#[derive(Debug, Default)]
pub struct Commit {
    /// (request, tokens gained) for every running request.
    pub gained: Vec<(RequestId, u32)>,
    /// Engine steps executed (fractional during partial commits).
    pub steps: f64,
    /// Wall time spent.
    pub elapsed: SimTime,
    /// Tokens gained in excess of one-per-step (speculative gains).
    pub accepted_tokens: f64,
}

#[derive(Debug)]
pub struct Instance {
    pub id: InstanceId,
    pub capacity_tokens: u64,
    pub alloc: PagedAllocator,
    /// Resident batch, in ascending-id order (a dense sorted table —
    /// iteration order feeds commit/finish event sequences and is part
    /// of the determinism contract; see [`SortedVecMap`]).
    pub running: SortedVecMap<RequestId, RunningReq>,
    /// KV tokens reserved for assignments whose transfer/prefill is still
    /// in flight (request -> reserved tokens).
    pub pending: SortedVecMap<RequestId, u64>,
    pub interval: Option<Interval>,
    /// Bumped on every state change; stale wake events are ignored.
    pub epoch: u64,
    pub busy: SimTime,
    pub steps_total: u64,
    /// Fault layer: false while the instance is crashed or reclaimed.
    /// Down instances hold no requests and receive no assignments.
    pub up: bool,
    /// Fault layer: multiplier on modeled step time (1.0 = full speed,
    /// > 1.0 = straggler under an `InstanceSlowdown` fault).
    pub slow_factor: f64,
}

impl Instance {
    pub fn new(id: InstanceId, capacity_tokens: u64, block_tokens: u32) -> Self {
        Instance {
            id,
            capacity_tokens,
            alloc: PagedAllocator::new(capacity_tokens, block_tokens),
            running: SortedVecMap::new(),
            pending: SortedVecMap::new(),
            interval: None,
            epoch: 0,
            busy: SimTime::ZERO,
            steps_total: 0,
            up: true,
            slow_factor: 1.0,
        }
    }

    pub fn batch_size(&self) -> usize {
        self.running.len()
    }

    /// Tokens of admission headroom: capacity × target_util minus used
    /// minus in-flight reservations.
    pub fn admission_headroom(&self, target_util: f64) -> u64 {
        let budget = (self.capacity_tokens as f64 * target_util) as u64;
        // Count real block consumption (not raw tokens) and leave one
        // block of rounding slack per resident/incoming request, so that
        // admitted chunks can always grow to their reservation.
        let block = self.alloc.block_tokens() as u64;
        let slack =
            (self.running.len() + self.pending.len() + 1) as u64 * block;
        let used = self.alloc.used_block_tokens()
            + self.pending.values().sum::<u64>()
            + slack;
        budget.saturating_sub(used)
    }

    /// Commit the current interval's progress up to `now`. Does NOT
    /// mutate the allocator or request states — the driver applies the
    /// returned gains so it can interleave pool/buffer bookkeeping.
    pub fn commit_until(&mut self, now: SimTime) -> Commit {
        let Some(iv) = self.interval.take() else {
            return Commit::default();
        };
        let elapsed_us = now.as_micros().saturating_sub(iv.start.as_micros());
        let steps =
            (elapsed_us as f64 / iv.step_us as f64).min(iv.steps as f64);
        let mut commit = Commit {
            steps,
            elapsed: SimTime::from_micros(elapsed_us.min(iv.step_us * iv.steps)),
            ..Default::default()
        };
        for (id, r) in self.running.iter_mut() {
            let raw = r.frac + r.rate * steps;
            let gain = (raw.floor() as u64).min(r.interval_budget as u64) as u32;
            r.frac = if (raw.floor() as u64) <= r.interval_budget as u64 {
                raw - raw.floor()
            } else {
                0.0 // budget-clipped: discard overshoot
            };
            commit.gained.push((*id, gain));
            commit.accepted_tokens += (gain as f64 - steps).max(0.0);
        }
        self.busy += commit.elapsed;
        self.steps_total += steps.round() as u64;
        self.epoch += 1;
        commit
    }

    /// Install a new interval (driver computed rates/boundaries).
    pub fn set_interval(&mut self, iv: Interval) {
        debug_assert!(self.interval.is_none(), "interval already in flight");
        debug_assert!(iv.steps >= 1 && iv.step_us >= 1);
        self.interval = Some(iv);
        self.epoch += 1;
    }

    pub fn kv_utilization(&self) -> f64 {
        self.alloc.utilization()
    }
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use super::*;

    fn inst() -> Instance {
        Instance::new(InstanceId(0), 10_000, 16)
    }

    fn run_req(rate: f64, budget: u32) -> RunningReq {
        RunningReq {
            rate,
            gamma: 0,
            frac: 0.0,
            interval_budget: budget,
            high_priority: false,
            started_at: SimTime::ZERO,
        }
    }

    #[test]
    fn full_commit_gains_rate_times_steps() {
        let mut i = inst();
        i.running.insert(RequestId(1), run_req(1.0, 1000));
        i.running.insert(RequestId(2), run_req(2.5, 1000));
        i.set_interval(Interval {
            start: SimTime::ZERO,
            step_us: 1000,
            steps: 10,
        });
        let c = i.commit_until(SimTime::from_micros(10_000));
        assert_eq!(c.steps, 10.0);
        let gains: BTreeMap<_, _> = c.gained.into_iter().collect();
        assert_eq!(gains[&RequestId(1)], 10);
        assert_eq!(gains[&RequestId(2)], 25);
        assert!((c.accepted_tokens - 15.0).abs() < 1e-9);
        assert_eq!(i.busy, SimTime::from_micros(10_000));
    }

    #[test]
    fn partial_commit_prorates() {
        let mut i = inst();
        i.running.insert(RequestId(1), run_req(2.0, 1000));
        i.set_interval(Interval {
            start: SimTime::ZERO,
            step_us: 1000,
            steps: 10,
        });
        let c = i.commit_until(SimTime::from_micros(5_500));
        assert!((c.steps - 5.5).abs() < 1e-9);
        assert_eq!(c.gained[0].1, 11);
        assert!(i.interval.is_none());
    }

    #[test]
    fn budget_clips_gain() {
        let mut i = inst();
        i.running.insert(RequestId(1), run_req(3.0, 7));
        i.set_interval(Interval {
            start: SimTime::ZERO,
            step_us: 100,
            steps: 10,
        });
        let c = i.commit_until(SimTime::from_micros(1_000));
        assert_eq!(c.gained[0].1, 7);
    }

    #[test]
    fn fractional_progress_carries() {
        let mut i = inst();
        i.running.insert(RequestId(1), run_req(1.5, 1000));
        i.set_interval(Interval {
            start: SimTime::ZERO,
            step_us: 1000,
            steps: 1,
        });
        let c1 = i.commit_until(SimTime::from_micros(1000));
        assert_eq!(c1.gained[0].1, 1); // 1.5 -> 1 token + 0.5 carried
        i.set_interval(Interval {
            start: SimTime::from_micros(1000),
            step_us: 1000,
            steps: 1,
        });
        let c2 = i.commit_until(SimTime::from_micros(2000));
        assert_eq!(c2.gained[0].1, 2); // 0.5 + 1.5 = 2.0
    }

    #[test]
    fn epoch_bumps_on_changes() {
        let mut i = inst();
        let e0 = i.epoch;
        i.running.insert(RequestId(1), run_req(1.0, 10));
        i.set_interval(Interval {
            start: SimTime::ZERO,
            step_us: 1,
            steps: 1,
        });
        assert!(i.epoch > e0);
        let e1 = i.epoch;
        i.commit_until(SimTime::from_micros(1));
        assert!(i.epoch > e1);
    }

    #[test]
    fn admission_headroom_counts_pending_and_block_slack() {
        let mut i = inst();
        // Empty: full budget minus one block of rounding slack.
        assert_eq!(i.admission_headroom(1.0), 10_000 - 16);
        i.alloc.grow(RequestId(1), 4000); // exactly 250 blocks
        i.running.insert(
            RequestId(1),
            run_req(1.0, 10),
        );
        i.pending.insert(RequestId(2), 1000);
        // budget 10000 − used 4000 − pending 1000 − slack 3×16.
        assert_eq!(i.admission_headroom(1.0), 5_000 - 48);
        // 50% target utilization: budget 5000 < charges -> 0.
        assert_eq!(i.admission_headroom(0.5), 0);
        // Block rounding is charged: one more token -> one more block.
        i.alloc.grow(RequestId(1), 1);
        assert_eq!(i.admission_headroom(1.0), 5_000 - 48 - 16);
    }
}
