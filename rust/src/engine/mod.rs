//! The inference-engine substrate: a vLLM-like instance model with
//! continuous batching, paged KV, preemption, and a calibrated step-time
//! cost model — plus the cluster simulation driver that advances a fleet
//! of instances through a rollout iteration under a pluggable scheduler.

pub mod cluster;
pub mod costmodel;
pub mod instance;

pub use cluster::{ClusterSim, RolloutOutcome};
pub use costmodel::CostModel;
pub use instance::{Instance, RunningReq};
