//! Engine step-time cost model.
//!
//! Decode is memory-bound: every forward step streams the (active) weights
//! once and reads the KV of all batched requests; verification adds a
//! compute term that grows with the number of processed token positions
//! (B × (γ+1)). Prefill is compute-bound. The paper's throughput model in
//! §3.4.1 — T_SD = (1-α)(D + T(B,γ)) / (1-α^{γ+1}) — is evaluated on top
//! of these primitives by the MBA policy.

use crate::config::HardwareConfig;
use crate::sim::clock::SimTime;

#[derive(Debug, Clone)]
pub struct CostModel {
    hw: HardwareConfig,
}

impl CostModel {
    pub fn new(hw: &HardwareConfig) -> Self {
        CostModel { hw: hw.clone() }
    }

    /// One engine forward step over `batch` requests whose KV totals
    /// `kv_tokens`, processing `positions` token positions in total
    /// (= batch for plain decode; = Σ(γ_i + 1) for verification).
    pub fn step_time(
        &self,
        batch: usize,
        kv_tokens: u64,
        positions: u64,
    ) -> SimTime {
        if batch == 0 {
            return SimTime::ZERO;
        }
        let kv_bytes = kv_tokens as f64 * self.hw.kv_bytes_per_token as f64;
        let mem = self.hw.weight_read_time.as_secs_f64()
            + kv_bytes / self.hw.hbm_bw;
        let compute =
            positions as f64 * self.hw.flops_per_token / self.hw.flops;
        self.hw.step_overhead + SimTime::from_secs_f64(mem.max(compute))
    }

    /// Prefill (or re-prefill after preemption) of `tokens` tokens:
    /// compute-bound, floor of one weight stream.
    pub fn prefill_time(&self, tokens: u64) -> SimTime {
        let compute =
            tokens as f64 * self.hw.flops_per_token / self.hw.flops;
        self.hw.step_overhead
            + SimTime::from_secs_f64(
                compute.max(self.hw.weight_read_time.as_secs_f64()),
            )
    }

    /// The §3.4.1 expected time for SD to produce one token per request:
    /// T_SD = (1-α)(D + T(B,γ)) / (1-α^{γ+1}).
    pub fn t_sd(
        &self,
        batch: usize,
        kv_tokens: u64,
        gamma: u32,
        alpha: f64,
        draft_cost: SimTime,
    ) -> f64 {
        let t = self
            .step_time(batch, kv_tokens, batch as u64 * (gamma as u64 + 1));
        let alpha = alpha.clamp(0.0, 0.999);
        let accept = (1.0 - alpha.powi(gamma as i32 + 1)) / (1.0 - alpha);
        (t.as_secs_f64() + draft_cost.as_secs_f64()) / accept
    }

    /// Expected generated tokens per verify step at acceptance rate alpha
    /// with draft length gamma (including the bonus token).
    pub fn expected_accept_len(gamma: u32, alpha: f64) -> f64 {
        let alpha = alpha.clamp(0.0, 0.999);
        (1.0 - alpha.powi(gamma as i32 + 1)) / (1.0 - alpha)
    }

    pub fn hw(&self) -> &HardwareConfig {
        &self.hw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;

    fn cm() -> CostModel {
        CostModel::new(&TaskPreset::Moonlight.workload().hw)
    }

    #[test]
    fn decode_memory_bound_grows_with_kv() {
        let m = cm();
        let a = m.step_time(32, 100_000, 32);
        let b = m.step_time(32, 1_000_000, 32);
        assert!(b > a, "{a:?} vs {b:?}");
    }

    #[test]
    fn small_batch_verify_nearly_free() {
        // §3.4.1: when B is small, T(B, γ) ≈ T(B, 1) — verification of a
        // few positions hides under the weight-stream floor.
        let m = cm();
        let t1 = m.step_time(1, 50_000, 1);
        let t8 = m.step_time(1, 50_000, 8);
        let ratio = t8.as_secs_f64() / t1.as_secs_f64();
        assert!(ratio < 1.05, "ratio {ratio}");
    }

    #[test]
    fn large_batch_verify_costs() {
        // At large batch (modest KV) the compute term dominates and γ
        // matters.
        let m = cm();
        let t1 = m.step_time(256, 500_000, 256);
        let t8 = m.step_time(256, 500_000, 256 * 8);
        assert!(
            t8.as_secs_f64() > 1.5 * t1.as_secs_f64(),
            "{t1:?} vs {t8:?}"
        );
    }

    #[test]
    fn prefill_scales_with_tokens() {
        let m = cm();
        let a = m.prefill_time(1_000);
        let b = m.prefill_time(100_000);
        assert!(b.as_secs_f64() > 10.0 * a.as_secs_f64());
    }

    #[test]
    fn t_sd_beneficial_at_small_batch_only() {
        let m = cm();
        let kv = 200_000;
        // Small batch: SD at γ=4, α=0.7 beats plain decode.
        let plain_small = m.step_time(4, kv, 4).as_secs_f64();
        let sd_small = m.t_sd(4, kv, 4, 0.7, SimTime::from_micros(200));
        assert!(sd_small < plain_small, "{sd_small} vs {plain_small}");
        // Huge batch: same SD config loses (compute-bound verification).
        let plain_big = m.step_time(512, kv, 512).as_secs_f64();
        let sd_big = m.t_sd(512, kv, 4, 0.7, SimTime::from_micros(200));
        assert!(sd_big > plain_big, "{sd_big} vs {plain_big}");
    }

    #[test]
    fn expected_accept_len_formula() {
        assert!((CostModel::expected_accept_len(0, 0.9) - 1.0).abs() < 1e-9);
        // γ=1, α=0.5: 1 + 0.5 = 1.5.
        assert!(
            (CostModel::expected_accept_len(1, 0.5) - 1.5).abs() < 1e-9
        );
        // γ→∞, α=0.5 → 2.0.
        assert!((CostModel::expected_accept_len(30, 0.5) - 2.0).abs() < 1e-6);
    }
}
