//! Multi-path (beam) drafting on the CST (paper §3.4.2: "capable of
//! returning multiple candidate paths via a beam-search mechanism").
//!
//! Each candidate path is scored by the product of per-step transition
//! probabilities (child count / parent count — SuffixDecoding-style suffix
//! probabilities); low-confidence candidates are filtered by
//! `min_confidence`.

use super::cst::Cst;

/// One draft candidate.
#[derive(Debug, Clone, PartialEq)]
pub struct DraftPath {
    pub tokens: Vec<u32>,
    /// Product of per-step transition probabilities.
    pub confidence: f64,
}

/// Beam-search the CST for up to `top_k` candidate continuations of
/// `pattern`, each up to `max_tokens` long.
pub fn speculate_multipath(
    cst: &Cst,
    pattern: &[u32],
    max_tokens: usize,
    lookup_max: usize,
    lookup_min: usize,
    top_k: usize,
    min_confidence: f64,
) -> Vec<DraftPath> {
    let start = pattern.len().saturating_sub(lookup_max);
    let (state, matched) = cst.match_suffix(&pattern[start..]);
    if top_k == 0 || max_tokens == 0 {
        return vec![];
    }
    let Some((state, _)) =
        cst.backoff_to_continuation(state, matched, lookup_min)
    else {
        return vec![];
    };

    #[derive(Clone)]
    struct Beam {
        state: u32,
        tokens: Vec<u32>,
        conf: f64,
    }

    let mut beams = vec![Beam {
        state,
        tokens: vec![],
        conf: 1.0,
    }];
    let mut finished: Vec<DraftPath> = vec![];

    for _ in 0..max_tokens {
        let mut next: Vec<Beam> = vec![];
        for b in &beams {
            let total: u64 = cst
                .transitions(b.state)
                .map(|(_, _, cnt)| cnt)
                .sum::<u64>()
                .max(1);
            let mut expanded = false;
            for (c, t, cnt) in cst.transitions(b.state) {
                let conf = b.conf * cnt as f64 / total as f64;
                if conf < min_confidence {
                    continue;
                }
                let mut tokens = b.tokens.clone();
                tokens.push(c);
                next.push(Beam {
                    state: t,
                    tokens,
                    conf,
                });
                expanded = true;
            }
            if !expanded && !b.tokens.is_empty() {
                finished.push(DraftPath {
                    tokens: b.tokens.clone(),
                    confidence: b.conf,
                });
            }
        }
        if next.is_empty() {
            break;
        }
        next.sort_by(|a, b| {
            b.conf
                .partial_cmp(&a.conf)
                .unwrap()
                .then_with(|| a.tokens.cmp(&b.tokens))
        });
        next.truncate(top_k);
        beams = next;
    }
    finished.extend(beams.into_iter().filter(|b| !b.tokens.is_empty()).map(
        |b| DraftPath {
            tokens: b.tokens,
            confidence: b.conf,
        },
    ));
    finished.sort_by(|a, b| {
        b.confidence
            .partial_cmp(&a.confidence)
            .unwrap()
            .then_with(|| a.tokens.cmp(&b.tokens))
    });
    finished.truncate(top_k);
    finished
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus_cst() -> Cst {
        let mut cst = Cst::new();
        // After [1, 2]: continuation [3, 4] twice, [5, 6] once.
        cst.append(0, 0, &[1, 2, 3, 4, 9, 1, 2, 3, 4]);
        cst.append(1, 0, &[1, 2, 5, 6]);
        cst
    }

    #[test]
    fn returns_ranked_candidates() {
        let cst = corpus_cst();
        let paths = speculate_multipath(&cst, &[1, 2], 2, 8, 1, 2, 0.0);
        assert_eq!(paths.len(), 2);
        assert_eq!(paths[0].tokens, vec![3, 4]);
        assert_eq!(paths[1].tokens, vec![5, 6]);
        assert!(paths[0].confidence > paths[1].confidence);
    }

    #[test]
    fn top_k_one_equals_linear_speculation() {
        let cst = corpus_cst();
        let linear = cst.speculate(&[1, 2], 2, 8, 1);
        let paths = speculate_multipath(&cst, &[1, 2], 2, 8, 1, 1, 0.0);
        assert_eq!(paths[0].tokens, linear);
    }

    #[test]
    fn confidence_filter_prunes() {
        let cst = corpus_cst();
        // [5, 6] branch has confidence 1/3 at the first step.
        let paths = speculate_multipath(&cst, &[1, 2], 2, 8, 1, 4, 0.5);
        assert_eq!(paths.len(), 1);
        assert_eq!(paths[0].tokens, vec![3, 4]);
    }

    #[test]
    fn lookup_min_blocks_weak_matches() {
        let cst = corpus_cst();
        let paths = speculate_multipath(&cst, &[7, 7, 7], 2, 8, 1, 2, 0.0);
        assert!(paths.is_empty());
    }

    #[test]
    fn confidences_multiply_along_path() {
        let cst = corpus_cst();
        let paths = speculate_multipath(&cst, &[1, 2], 1, 8, 1, 2, 0.0);
        // First step out of [1,2]: counts 2 (token 3) vs 1 (token 5).
        assert!((paths[0].confidence - 2.0 / 3.0).abs() < 1e-9);
        assert!((paths[1].confidence - 1.0 / 3.0).abs() < 1e-9);
    }
}
