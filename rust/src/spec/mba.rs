//! Marginal-Benefit-Aware adaptive speculation — paper Algorithm 1,
//! verbatim.
//!
//! Given the high/low-priority batch sizes, the per-position acceptance
//! probabilities β[1..], a per-request budget cap γ_max and the priority
//! factor λ, choose draft lengths (γ_h, γ_l):
//!
//! 1. γ* = argmin_γ T_SD(B, γ) for the combined batch — the
//!    throughput-optimal uniform draft length;
//! 2. Γ* = γ*·B is the total token budget;
//! 3. if Γ* can't even give every high-priority request one draft token,
//!    disable SD entirely;
//! 4. otherwise allocate greedily by marginal benefit
//!    B_h·(β[γ_h] − β[γ_h+1])  vs  λ · B_l·(β[γ_l] − β[γ_l+1]).

use crate::engine::costmodel::CostModel;
use crate::sim::clock::SimTime;

/// Inputs to one MBA invocation (collected online by the coordinator).
#[derive(Debug, Clone)]
pub struct MbaInputs {
    pub batch_high: usize,
    pub batch_low: usize,
    /// β[k] = acceptance probability at draft position k (1-indexed via
    /// `beta(k)`; β[0] is unused). Must be non-increasing.
    pub beta: Vec<f64>,
    pub gamma_max: u32,
    pub lambda: f64,
    /// Mean acceptance rate α = E(β), for the T_SD model.
    pub alpha: f64,
    /// Total KV tokens currently batched (for the step-time model).
    pub kv_tokens: u64,
    /// Draft cost as a function of γ (flat per invocation here; the
    /// caller folds per-strategy shape in).
    pub draft_cost_per_gamma: SimTime,
}

impl MbaInputs {
    fn beta(&self, k: u32) -> f64 {
        // β beyond the profiled horizon decays to 0 (no benefit).
        self.beta.get(k as usize - 1).copied().unwrap_or(0.0)
    }
}

/// Result: draft token counts for high- and low-priority requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MbaDecision {
    pub gamma_high: u32,
    pub gamma_low: u32,
}

/// Paper Algorithm 1.
pub fn mba_allocate(cost: &CostModel, inp: &MbaInputs) -> MbaDecision {
    let b = inp.batch_high + inp.batch_low;
    if b == 0 {
        return MbaDecision {
            gamma_high: 0,
            gamma_low: 0,
        };
    }

    // Line 2: γ* = argmin_γ T_SD(B, γ). γ = 0 means plain decode.
    let draft_cost = |gamma: u32| {
        SimTime::from_micros(
            inp.draft_cost_per_gamma.as_micros() * gamma as u64,
        )
    };
    let t_plain = cost.step_time(b, inp.kv_tokens, b as u64).as_secs_f64();
    let mut best_gamma = 0u32;
    let mut best_t = t_plain;
    for gamma in 1..=inp.gamma_max {
        let t = cost.t_sd(b, inp.kv_tokens, gamma, inp.alpha, draft_cost(gamma));
        if t < best_t {
            best_t = t;
            best_gamma = gamma;
        }
    }

    // Line 3: total token budget.
    let budget = best_gamma as u64 * b as u64;

    // Line 4-5: not enough budget to serve high priority at all.
    if budget < inp.batch_high as u64 {
        return MbaDecision {
            gamma_high: 0,
            gamma_low: 0,
        };
    }

    // Lines 7-18: greedy marginal-benefit allocation.
    let (bh, bl) = (inp.batch_high as u64, inp.batch_low as u64);
    let mut gamma_h = 1u32;
    let mut gamma_l = 0u32;
    let mut remaining = budget - bh;
    while remaining > 0 {
        let benefit_h = bh as f64
            * (inp.beta(gamma_h) - inp.beta(gamma_h + 1)).max(0.0);
        let benefit_l = if bl > 0 {
            bl as f64 * (inp.beta(gamma_l.max(1)) - inp.beta(gamma_l + 1)).max(0.0)
        } else {
            0.0
        };
        if benefit_h > inp.lambda * benefit_l
            && gamma_h < inp.gamma_max
            && remaining >= bh
        {
            gamma_h += 1;
            remaining -= bh;
        } else if bl > 0 && gamma_l < inp.gamma_max && remaining >= bl {
            gamma_l += 1;
            remaining -= bl;
        } else {
            break;
        }
    }
    MbaDecision {
        gamma_high: gamma_h,
        gamma_low: gamma_l,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;

    fn cost() -> CostModel {
        CostModel::new(&TaskPreset::Moonlight.workload().hw)
    }

    fn inputs(bh: usize, bl: usize) -> MbaInputs {
        MbaInputs {
            batch_high: bh,
            batch_low: bl,
            beta: vec![0.7, 0.6, 0.5, 0.4, 0.3, 0.22, 0.15, 0.1],
            gamma_max: 8,
            lambda: 2.0,
            alpha: 0.55,
            kv_tokens: 100_000,
            draft_cost_per_gamma: SimTime::from_micros(30),
        }
    }

    #[test]
    fn empty_batch_is_noop() {
        let d = mba_allocate(&cost(), &inputs(0, 0));
        assert_eq!(d, MbaDecision { gamma_high: 0, gamma_low: 0 });
    }

    #[test]
    fn small_batch_gets_generous_budget() {
        // Small batch: SD is cheap, both classes get drafts; high ≥ low.
        let d = mba_allocate(&cost(), &inputs(2, 6));
        assert!(d.gamma_high >= 1);
        assert!(d.gamma_high >= d.gamma_low, "{d:?}");
        assert!(d.gamma_high <= 8 && d.gamma_low <= 8);
    }

    #[test]
    fn huge_batch_disables_sd() {
        // Compute-bound regime (large batch, modest KV): γ* = 0 ⇒
        // budget below B_h ⇒ (0, 0).
        let mut inp = inputs(600, 3000);
        inp.kv_tokens = 1_000_000;
        let d = mba_allocate(&cost(), &inp);
        assert_eq!(d, MbaDecision { gamma_high: 0, gamma_low: 0 });
    }

    #[test]
    fn high_priority_dominates_when_lambda_large() {
        // Mid-size batch: the verify compute term makes γ* < γ_max, so
        // the budget is scarce; λ→∞ routes nearly all of it high.
        let mut inp = inputs(100, 100);
        inp.kv_tokens = 2_000_000;
        inp.lambda = 1000.0;
        let d = mba_allocate(&cost(), &inp);
        assert!(
            d.gamma_high > d.gamma_low,
            "high priority must dominate: {d:?}"
        );
        assert!(d.gamma_low <= 2, "{d:?}");
    }

    #[test]
    fn lambda_one_balances() {
        let mut inp = inputs(4, 4);
        inp.lambda = 1.0;
        let d = mba_allocate(&cost(), &inp);
        // With symmetric batches and λ=1 the split is near-even.
        assert!(
            (d.gamma_high as i64 - d.gamma_low as i64).abs() <= 2,
            "{d:?}"
        );
    }

    #[test]
    fn budget_and_caps_respected() {
        for (bh, bl) in [(1, 0), (1, 31), (16, 16), (0, 8), (5, 200)] {
            let inp = inputs(bh, bl);
            let d = mba_allocate(&cost(), &inp);
            assert!(d.gamma_high <= inp.gamma_max);
            assert!(d.gamma_low <= inp.gamma_max);
            if bh == 0 {
                // Degenerate: all budget flows to low priority; γ_h is
                // meaningless but must stay bounded.
                continue;
            }
            // Reconstruct budget bound: γh·Bh + γl·Bl ≤ γ*·B for the γ*
            // the algorithm chose; we can't see γ* directly, but the cap
            // γ ≤ γ_max bounds both.
        }
    }

    #[test]
    fn only_high_priority_present() {
        let d = mba_allocate(&cost(), &inputs(8, 0));
        assert!(d.gamma_high >= 1);
        assert_eq!(d.gamma_low, 0);
    }
}
