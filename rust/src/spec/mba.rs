//! Marginal-Benefit-Aware adaptive speculation — paper Algorithm 1,
//! verbatim.
//!
//! Given the high/low-priority batch sizes, the per-position acceptance
//! probabilities β[1..], a per-request budget cap γ_max and the priority
//! factor λ, choose draft lengths (γ_h, γ_l):
//!
//! 1. γ* = argmin_γ T_SD(B, γ) for the combined batch — the
//!    throughput-optimal uniform draft length;
//! 2. Γ* = γ*·B is the total token budget;
//! 3. if Γ* can't even give every high-priority request one draft token,
//!    disable SD entirely;
//! 4. otherwise allocate greedily by priority-weighted marginal benefit
//!    *per budget token*: λ·(β[γ_h] − β[γ_h+1]) vs (β[γ_l] − β[γ_l+1]).
//!    One more draft position for a class costs `batch` budget tokens and
//!    yields `batch · Δβ` expected accepted tokens, so the batch factors
//!    cancel; λ ≥ 1 weights the high-priority (probe) class. β[0] = 1 by
//!    definition (position 0 is the already-verified context), so the 0→1
//!    marginal benefit of a class's *first* draft token is 1 − β[1] — the
//!    largest marginal of all, which is what keeps low priority from being
//!    starved of its first token.

use crate::engine::costmodel::CostModel;
use crate::sim::clock::SimTime;

/// Inputs to one MBA invocation (collected online by the coordinator).
#[derive(Debug, Clone)]
pub struct MbaInputs {
    pub batch_high: usize,
    pub batch_low: usize,
    /// β[k] = acceptance probability at draft position k (1-indexed via
    /// `beta(k)`; `beta(0)` is defined as 1.0 — the already-verified
    /// context). Must be non-increasing.
    pub beta: Vec<f64>,
    pub gamma_max: u32,
    pub lambda: f64,
    /// Mean acceptance rate α = E(β), for the T_SD model.
    pub alpha: f64,
    /// Total KV tokens currently batched (for the step-time model).
    pub kv_tokens: u64,
    /// Draft cost as a function of γ (flat per invocation here; the
    /// caller folds per-strategy shape in).
    pub draft_cost_per_gamma: SimTime,
}

impl MbaInputs {
    fn beta(&self, k: u32) -> f64 {
        // β[0] = 1: position 0 is the verified context itself, always
        // accepted, so the 0→1 marginal benefit is 1 − β[1] (Alg. 1).
        if k == 0 {
            return 1.0;
        }
        // β beyond the profiled horizon decays to 0 (no benefit).
        self.beta.get(k as usize - 1).copied().unwrap_or(0.0)
    }
}

/// Result: draft token counts for high- and low-priority requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MbaDecision {
    pub gamma_high: u32,
    pub gamma_low: u32,
}

/// Line 2 of Algorithm 1: γ* = argmin_γ T_SD(B, γ) for the combined
/// batch — the throughput-optimal uniform draft length (0 = plain
/// decode). Exposed so tests can reconstruct the Γ* = γ*·B token budget
/// that bounds every [`mba_allocate`] decision.
pub fn optimal_uniform_gamma(cost: &CostModel, inp: &MbaInputs) -> u32 {
    let b = inp.batch_high + inp.batch_low;
    if b == 0 {
        return 0;
    }
    let draft_cost = |gamma: u32| {
        SimTime::from_micros(
            inp.draft_cost_per_gamma.as_micros() * gamma as u64,
        )
    };
    let t_plain = cost.step_time(b, inp.kv_tokens, b as u64).as_secs_f64();
    let mut best_gamma = 0u32;
    let mut best_t = t_plain;
    for gamma in 1..=inp.gamma_max {
        let t = cost.t_sd(b, inp.kv_tokens, gamma, inp.alpha, draft_cost(gamma));
        if t < best_t {
            best_t = t;
            best_gamma = gamma;
        }
    }
    best_gamma
}

/// Paper Algorithm 1.
pub fn mba_allocate(cost: &CostModel, inp: &MbaInputs) -> MbaDecision {
    let b = inp.batch_high + inp.batch_low;
    if b == 0 {
        return MbaDecision {
            gamma_high: 0,
            gamma_low: 0,
        };
    }

    // Line 2-3: γ* and the total token budget Γ* = γ*·B.
    let budget = optimal_uniform_gamma(cost, inp) as u64 * b as u64;

    // Line 4-5: no budget at all (γ* = 0), or not enough to serve high
    // priority even one token each — disable SD.
    if budget == 0 || budget < inp.batch_high as u64 {
        return MbaDecision {
            gamma_high: 0,
            gamma_low: 0,
        };
    }

    // Lines 7-18: greedy allocation by priority-weighted marginal
    // benefit per budget token (see module docs: the batch factors
    // cancel, λ weights the high-priority class, and β[0] = 1 makes a
    // class's first token its most valuable). When the preferred class
    // is capped (γ_max) or can't afford its batch, the token goes to
    // the other class instead of being dropped; ties go high.
    let (bh, bl) = (inp.batch_high as u64, inp.batch_low as u64);
    // Every high-priority request is guaranteed its first token up
    // front (the budget check above ensures it fits); an empty high
    // batch gets γ_h = 0 instead of a meaningless 1.
    let mut gamma_h = u32::from(bh > 0);
    let mut gamma_l = 0u32;
    let mut remaining = budget - bh;
    while remaining > 0 {
        let can_h = bh > 0 && gamma_h < inp.gamma_max && remaining >= bh;
        let can_l = bl > 0 && gamma_l < inp.gamma_max && remaining >= bl;
        if !can_h && !can_l {
            break;
        }
        let benefit_h = (inp.beta(gamma_h) - inp.beta(gamma_h + 1)).max(0.0);
        let benefit_l = (inp.beta(gamma_l) - inp.beta(gamma_l + 1)).max(0.0);
        if can_h && (!can_l || inp.lambda * benefit_h >= benefit_l) {
            gamma_h += 1;
            remaining -= bh;
        } else {
            gamma_l += 1;
            remaining -= bl;
        }
    }
    MbaDecision {
        gamma_high: gamma_h,
        gamma_low: gamma_l,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;

    fn cost() -> CostModel {
        CostModel::new(&TaskPreset::Moonlight.workload().hw)
    }

    fn inputs(bh: usize, bl: usize) -> MbaInputs {
        MbaInputs {
            batch_high: bh,
            batch_low: bl,
            beta: vec![0.7, 0.6, 0.5, 0.4, 0.3, 0.22, 0.15, 0.1],
            gamma_max: 8,
            lambda: 2.0,
            alpha: 0.55,
            kv_tokens: 100_000,
            draft_cost_per_gamma: SimTime::from_micros(30),
        }
    }

    #[test]
    fn empty_batch_is_noop() {
        let d = mba_allocate(&cost(), &inputs(0, 0));
        assert_eq!(d, MbaDecision { gamma_high: 0, gamma_low: 0 });
    }

    #[test]
    fn small_batch_gets_generous_budget() {
        // Small batch: SD is cheap, both classes get drafts; high ≥ low.
        let d = mba_allocate(&cost(), &inputs(2, 6));
        assert!(d.gamma_high >= 1);
        assert!(d.gamma_high >= d.gamma_low, "{d:?}");
        assert!(d.gamma_high <= 8 && d.gamma_low <= 8);
    }

    #[test]
    fn huge_batch_disables_sd() {
        // Compute-bound regime (large batch, modest KV): γ* = 0 ⇒
        // budget below B_h ⇒ (0, 0).
        let mut inp = inputs(600, 3000);
        inp.kv_tokens = 1_000_000;
        let d = mba_allocate(&cost(), &inp);
        assert_eq!(d, MbaDecision { gamma_high: 0, gamma_low: 0 });
    }

    #[test]
    fn high_priority_dominates_when_lambda_large() {
        // Mid-size batch: the verify compute term makes γ* < γ_max, so
        // the budget is scarce; λ→∞ routes nearly all of it high.
        let mut inp = inputs(100, 100);
        inp.kv_tokens = 2_000_000;
        inp.lambda = 1000.0;
        let d = mba_allocate(&cost(), &inp);
        assert!(
            d.gamma_high > d.gamma_low,
            "high priority must dominate: {d:?}"
        );
        assert!(d.gamma_low <= 2, "{d:?}");
    }

    #[test]
    fn lambda_one_balances() {
        let mut inp = inputs(4, 4);
        inp.lambda = 1.0;
        let d = mba_allocate(&cost(), &inp);
        // With symmetric batches and λ=1 the split is near-even.
        assert!(
            (d.gamma_high as i64 - d.gamma_low as i64).abs() <= 2,
            "{d:?}"
        );
    }

    #[test]
    fn budget_and_caps_respected() {
        // Property sweep over a deterministic pseudo-random input space
        // (xorshift — no external rand dep): `mba_allocate` must never
        // panic, both γ stay within γ_max, and the spend fits the
        // Γ* = γ*·B token budget reconstructed via
        // `optimal_uniform_gamma` — γh·Bh + γl·Bl ≤ γ*·B.
        let c = cost();
        let mut state = 0x9e37_79b9_7f4a_7c15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for case in 0..500 {
            let batch_high = (next() % 300) as usize;
            let batch_low = (next() % 300) as usize;
            // Non-increasing β profile of random length (possibly empty).
            let n_beta = (next() % 10) as usize;
            let mut beta = Vec::with_capacity(n_beta);
            let mut b = 0.95f64;
            for _ in 0..n_beta {
                beta.push(b);
                b *= 0.55 + (next() % 40) as f64 / 100.0;
            }
            let inp = MbaInputs {
                batch_high,
                batch_low,
                beta,
                gamma_max: (next() % 12) as u32, // including 0
                lambda: 1.0 + (next() % 80) as f64 / 10.0,
                alpha: (next() % 95) as f64 / 100.0,
                kv_tokens: next() % 4_000_000,
                draft_cost_per_gamma: SimTime::from_micros(next() % 200),
            };
            let d = mba_allocate(&c, &inp);
            assert!(d.gamma_high <= inp.gamma_max, "case {case}: {d:?} {inp:?}");
            assert!(d.gamma_low <= inp.gamma_max, "case {case}: {d:?} {inp:?}");
            let budget = optimal_uniform_gamma(&c, &inp) as u64
                * (batch_high + batch_low) as u64;
            let spend = d.gamma_high as u64 * batch_high as u64
                + d.gamma_low as u64 * batch_low as u64;
            assert!(
                spend <= budget,
                "case {case}: spend {spend} > budget {budget} ({d:?} {inp:?})"
            );
            if batch_high == 0 {
                assert_eq!(d.gamma_high, 0, "case {case}: {d:?}");
            }
        }
    }

    #[test]
    fn first_low_priority_token_not_starved() {
        // Regression for the β(1)−β(1)=0 bug: with λ = 1, symmetric
        // batches, and a budget that covers a first draft token for
        // every request (γ* = 1 here, so Γ* = B_h + B_l exactly), the
        // old formula scored the 0→1 low-priority marginal as zero and
        // spent the whole budget extending high priority (γ_l = 0).
        // With β[0] = 1 the 0→1 marginal is 1 − β[1] = 0.3 — larger
        // than high priority's 1→2 marginal of 0.1 — so low priority
        // must receive its first token.
        let mut inp = inputs(200, 200);
        inp.lambda = 1.0;
        inp.kv_tokens = 1_000_000;
        let d = mba_allocate(&cost(), &inp);
        assert!(d.gamma_high >= 1, "{d:?}");
        assert!(
            d.gamma_low >= 1,
            "low priority starved of its first draft token: {d:?}"
        );
    }

    #[test]
    fn only_high_priority_present() {
        let d = mba_allocate(&cost(), &inputs(8, 0));
        assert!(d.gamma_high >= 1);
        assert_eq!(d.gamma_low, 0);
    }
}
