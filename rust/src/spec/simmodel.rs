//! SD strategy models for the cluster simulator.
//!
//! The cluster sim advances generation in expectation (fluid token rates),
//! so each strategy is characterized by (a) a per-position acceptance
//! profile β[k] — which also yields α — and (b) a draft-cost model D(B,γ).
//! The grouped-CST profile's dependence on the number of same-group
//! reference streams is calibrated to our own token-level CST measurements
//! (Table 2 reproduction in `experiments::table2`), which in turn match
//! the paper's reported shape.

use crate::sim::clock::SimTime;

/// Which SD strategy a simulated engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SdStrategy {
    /// No speculative decoding.
    None,
    /// Seer: DGDS grouped CST + MBA adaptive draft lengths (§3.4).
    GroupedCst,
    /// Vanilla SuffixDecoding: per-request history CST only, static-ish
    /// draft budget (the paper's Moonlight SD baseline).
    SuffixDecoding,
    /// Separate small draft model (the Qwen2-VL baseline: Qwen2-7B-VL).
    DraftModel,
    /// Multi-token-prediction head, γ = 1 (the Kimi-K2 baseline).
    Mtp,
}

impl SdStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            SdStrategy::None => "none",
            SdStrategy::GroupedCst => "grouped-cst",
            SdStrategy::SuffixDecoding => "suffix-decoding",
            SdStrategy::DraftModel => "draft-model",
            SdStrategy::Mtp => "mtp",
        }
    }
}

/// Per-request context the model conditions on.
#[derive(Debug, Clone, Copy)]
pub struct SpecCtx {
    /// Tokens this request has generated (own-history signal).
    pub generated: u32,
    /// *Fresh* same-group sibling streams available as references:
    /// finished siblings plus concurrently-running ones with progress —
    /// all produced by the current policy.
    pub group_refs: usize,
    /// Historical reference streams replayed from a previous iteration
    /// (RhymeRL-style warm start via the `ContextStore`). These came
    /// from an *older* policy, so their draft value decays with
    /// [`SpecCtx::drift`] instead of counting like fresh siblings.
    pub warm_refs: usize,
    /// Policy drift (epoch-drift sigma) since the warm streams were
    /// produced; 0 = same policy, larger = history rhymes less.
    pub drift: f64,
    /// Multi-path branching factor in use (1 = linear).
    pub top_k: u32,
}

impl Default for SpecCtx {
    fn default() -> Self {
        SpecCtx {
            generated: 0,
            group_refs: 0,
            warm_refs: 0,
            drift: 0.0,
            top_k: 1,
        }
    }
}

impl SpecCtx {
    /// Effective reference-stream count: fresh siblings at full weight
    /// plus warm historical streams discounted by policy drift. The
    /// discount is linear and hits zero at drift σ = 0.25 — by then the
    /// length/token statistics of the old policy no longer predict the
    /// new one's outputs (RhymeRL's "history rhymes" fades as the
    /// policy moves).
    pub fn effective_refs(&self) -> f64 {
        let discount = (1.0 - 4.0 * self.drift).clamp(0.0, 1.0);
        self.group_refs as f64 + discount * self.warm_refs as f64
    }
}

/// Acceptance + cost profiles for one strategy.
#[derive(Debug, Clone)]
pub struct SpecSim {
    pub strategy: SdStrategy,
    /// Workload pattern richness in (0, 1]: how much repeated local
    /// structure the task's responses carry. Math CoT (Moonlight) is
    /// less templated than judge/VL boilerplate; scales the n-gram
    /// acceptance rates (not the draft-model/MTP ones, which predict
    /// from semantics rather than repetition).
    pub richness: f64,
}

impl SpecSim {
    pub fn new(strategy: SdStrategy) -> Self {
        SpecSim {
            strategy,
            richness: 1.0,
        }
    }

    pub fn with_richness(mut self, richness: f64) -> Self {
        self.richness = richness.clamp(0.05, 1.0);
        self
    }

    /// Base acceptance rate α given context.
    pub fn alpha(&self, ctx: &SpecCtx) -> f64 {
        let scale = match self.strategy {
            SdStrategy::GroupedCst | SdStrategy::SuffixDecoding => {
                self.richness
            }
            _ => 1.0,
        };
        scale * self.alpha_unscaled(ctx)
    }

    fn alpha_unscaled(&self, ctx: &SpecCtx) -> f64 {
        match self.strategy {
            SdStrategy::None => 0.0,
            SdStrategy::GroupedCst => {
                // Calibrated to Table 2: α(n=0) ≈ 0.41 rising to
                // α(n=15) ≈ 0.60, saturating; multi-path adds a small
                // bump (k=2: +0.025, k=4: +0.05). Warm historical
                // streams count through the drift-discounted
                // effective-reference total (see `SpecCtx::effective_refs`).
                let n = ctx.effective_refs();
                let base = 0.41 + 0.19 * (1.0 - (-n / 5.0).exp()) / (1.0 - (-3.0f64).exp());
                let mp = match ctx.top_k {
                    0 | 1 => 0.0,
                    2..=3 => 0.025,
                    _ => 0.05,
                };
                (base + mp + self.history_bonus(ctx)).min(0.75)
            }
            SdStrategy::SuffixDecoding => {
                // Own history only — the Table 2 n=0 row.
                (0.41 + self.history_bonus(ctx)).min(0.6)
            }
            // A real draft model understands semantics: higher α,
            // insensitive to group context.
            SdStrategy::DraftModel => 0.68,
            // One extra head: good single-token acceptance.
            SdStrategy::Mtp => 0.60,
        }
    }

    fn history_bonus(&self, ctx: &SpecCtx) -> f64 {
        // Longer own history → richer self-reference (saturates fast).
        0.04 * (1.0 - (-(ctx.generated as f64) / 4000.0).exp())
    }

    /// Per-position acceptance profile β[1..=horizon]: geometric decay
    /// around α (later draft positions are harder).
    pub fn beta_profile(&self, ctx: &SpecCtx, horizon: u32) -> Vec<f64> {
        let alpha = self.alpha(ctx);
        let decay: f64 = match self.strategy {
            SdStrategy::DraftModel => 0.97, // coherent long drafts
            SdStrategy::GroupedCst => 0.93,
            SdStrategy::SuffixDecoding => 0.88,
            _ => 0.85,
        };
        (0..horizon)
            .map(|k| alpha * decay.powi(k as i32))
            .collect()
    }

    /// Draft-generation cost D(B, γ) per engine step.
    pub fn draft_cost(&self, batch: usize, gamma: u32) -> SimTime {
        match self.strategy {
            SdStrategy::None => SimTime::ZERO,
            // DGDS: lookups run against the local snapshot, updates are
            // asynchronous and off the critical path — O(p+s) per request,
            // ~2 µs per draft token.
            SdStrategy::GroupedCst => {
                SimTime::from_micros((batch as u64 * gamma as u64 * 2).max(5))
            }
            // Synchronous per-request tree maintenance serializes with
            // the engine (the overhead §3.4.2 calls out): ~8 µs/token.
            SdStrategy::SuffixDecoding => {
                SimTime::from_micros((batch as u64 * gamma as u64 * 8).max(10))
            }
            // A 7B draft model forward per draft token: weight stream
            // ~0.6 ms per token on the instance's spare capacity.
            SdStrategy::DraftModel => {
                SimTime::from_micros(600 * gamma as u64 + 100)
            }
            // MTP head rides the main forward: tiny fixed cost.
            SdStrategy::Mtp => SimTime::from_micros(50),
        }
    }

    /// BubbleSpec-style draft-budget uplift: `boost` in [0, 1] is the
    /// share of this verify batch's draft generation backed by
    /// otherwise-idle instances (end-of-rollout bubbles). Spare draft
    /// capacity deepens the draft budget from `gamma` toward
    /// `gamma_max` — the MBA budget Γ* only rations the *instance's
    /// own* draft time, which bubble capacity does not consume.
    /// Inert for `None` and for requests SD already skipped (γ = 0).
    pub fn bubble_gamma(&self, gamma: u32, gamma_max: u32, boost: f64) -> u32 {
        if self.strategy == SdStrategy::None || gamma == 0 || boost <= 0.0 {
            return gamma;
        }
        let head = gamma_max.saturating_sub(gamma) as f64;
        gamma + (head * boost.clamp(0.0, 1.0)).round() as u32
    }

    /// Draft cost with the bubble-offloaded share removed from the
    /// critical path: the `boost` fraction of draft generation runs on
    /// idle instances, so the busy instance only pays the rest.
    pub fn bubble_draft_cost(
        &self,
        batch: usize,
        gamma: u32,
        boost: f64,
    ) -> SimTime {
        let full = self.draft_cost(batch, gamma);
        if boost <= 0.0 {
            return full;
        }
        SimTime::from_secs_f64(
            full.as_secs_f64() * (1.0 - boost.clamp(0.0, 1.0)),
        )
    }

    /// Default/preferred draft budget for strategies that do not use MBA.
    pub fn static_gamma(&self) -> u32 {
        match self.strategy {
            SdStrategy::None => 0,
            SdStrategy::GroupedCst => 8, // MBA overrides
            SdStrategy::SuffixDecoding => 16,
            SdStrategy::DraftModel => 3,
            SdStrategy::Mtp => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(refs: usize) -> SpecCtx {
        SpecCtx {
            generated: 2000,
            group_refs: refs,
            ..Default::default()
        }
    }

    #[test]
    fn grouped_alpha_grows_with_refs() {
        let s = SpecSim::new(SdStrategy::GroupedCst);
        let a0 = s.alpha(&ctx(0));
        let a5 = s.alpha(&ctx(5));
        let a15 = s.alpha(&ctx(15));
        assert!(a0 < a5 && a5 < a15, "{a0} {a5} {a15}");
        assert!(a15 <= 0.75);
    }

    #[test]
    fn grouped_beats_suffix_given_refs() {
        let g = SpecSim::new(SdStrategy::GroupedCst);
        let v = SpecSim::new(SdStrategy::SuffixDecoding);
        assert!(g.alpha(&ctx(8)) > v.alpha(&ctx(8)) + 0.05);
        // ...but degenerates to the same regime with no references.
        assert!((g.alpha(&ctx(0)) - v.alpha(&ctx(0))).abs() < 0.05);
    }

    #[test]
    fn multipath_bumps_alpha() {
        let s = SpecSim::new(SdStrategy::GroupedCst);
        let linear = s.alpha(&SpecCtx { top_k: 1, ..ctx(5) });
        let k4 = s.alpha(&SpecCtx { top_k: 4, ..ctx(5) });
        assert!(k4 > linear);
    }

    #[test]
    fn warm_refs_help_but_decay_with_drift() {
        let s = SpecSim::new(SdStrategy::GroupedCst);
        let cold = s.alpha(&ctx(0));
        let warm = |drift: f64| {
            s.alpha(&SpecCtx {
                warm_refs: 6,
                drift,
                ..ctx(0)
            })
        };
        // Same-policy history counts like fresh references.
        assert!(warm(0.0) > cold + 0.05, "{} vs {cold}", warm(0.0));
        assert!((warm(0.0) - s.alpha(&ctx(6))).abs() < 1e-12);
        // Monotone decay toward the cold rate as the policy drifts...
        assert!(warm(0.05) > warm(0.1));
        assert!(warm(0.1) > warm(0.2));
        // ...and fully decayed history is worth nothing.
        assert_eq!(warm(0.3), cold);
        // Fresh siblings are never discounted.
        let fresh = s.alpha(&SpecCtx { drift: 0.3, ..ctx(6) });
        assert_eq!(fresh, s.alpha(&ctx(6)));
    }

    #[test]
    fn bubble_boost_deepens_gamma_and_offloads_cost() {
        let s = SpecSim::new(SdStrategy::GroupedCst);
        // γ uplift grows toward γ_max with the boost fraction.
        assert_eq!(s.bubble_gamma(4, 8, 0.0), 4);
        assert_eq!(s.bubble_gamma(4, 8, 0.5), 6);
        assert_eq!(s.bubble_gamma(4, 8, 1.0), 8);
        // SD-disabled requests stay disabled; None stays inert.
        assert_eq!(s.bubble_gamma(0, 8, 1.0), 0);
        let none = SpecSim::new(SdStrategy::None);
        assert_eq!(none.bubble_gamma(4, 8, 1.0), 4);
        // Offloaded draft cost shrinks with the boost; never negative.
        let full = s.bubble_draft_cost(16, 8, 0.0);
        let half = s.bubble_draft_cost(16, 8, 0.5);
        let all = s.bubble_draft_cost(16, 8, 1.0);
        assert_eq!(full, s.draft_cost(16, 8));
        assert!(half < full && all <= half, "{full:?} {half:?} {all:?}");
    }

    #[test]
    fn beta_profile_non_increasing() {
        for strat in [
            SdStrategy::GroupedCst,
            SdStrategy::SuffixDecoding,
            SdStrategy::DraftModel,
            SdStrategy::Mtp,
        ] {
            let s = SpecSim::new(strat);
            let beta = s.beta_profile(&ctx(4), 8);
            assert!(beta.windows(2).all(|w| w[0] >= w[1]), "{strat:?}");
            assert!(beta[0] > 0.0);
        }
    }

    #[test]
    fn draft_model_costs_dominate() {
        let dm = SpecSim::new(SdStrategy::DraftModel);
        let cst = SpecSim::new(SdStrategy::GroupedCst);
        assert!(
            dm.draft_cost(8, 3).as_micros()
                > 10 * cst.draft_cost(8, 3).as_micros()
        );
    }

    #[test]
    fn none_is_inert() {
        let s = SpecSim::new(SdStrategy::None);
        assert_eq!(s.alpha(&ctx(10)), 0.0);
        assert_eq!(s.draft_cost(100, 8), SimTime::ZERO);
        assert_eq!(s.static_gamma(), 0);
    }
}
