//! Distributed Grouped Draft Server (paper §3.4.2 and Appendix A.2).
//!
//! Master/worker architecture over std threads and channels (the offline
//! environment has no tokio; DESIGN.md §2): a dedicated server thread owns
//! the per-group CSTs and applies `update_cst` appends *asynchronously* —
//! inference clients never wait for tree maintenance (the property that
//! distinguishes DGDS from serialized suffix-tree SD). Clients hold
//! shared handles fetched via `fetch_cst` and run `batch_speculate`
//! against them under read locks (modelling the zero-copy shared-memory
//! path of the paper's Table 6 API).
//!
//! API mapping (paper Table 5/6):
//! * `update_cst(group_id, request_id, prev_token_count, new_tokens)`
//! * `fetch_cst(group_ids) -> handles` (incremental: handles are shared)
//! * `register_group(group_id, ttl)`
//! * `batch_speculate(...)` on [`DraftClient`]
//!
//! Group lifetime is driven by a **logical clock** — one tick per
//! message the server processes — never by host wall time, so expiry is
//! a pure function of the message sequence (deterministic replay). An
//! expired group leaves a tombstone: late `update_cst`/`warm_start`
//! traffic for it is dropped rather than silently resurrecting the
//! group with a fresh default lifetime; resurrection requires an
//! explicit [`DraftServer::register_group`].

use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;

use super::cst::Cst;
use super::multipath::{speculate_multipath, DraftPath};

type GroupHandle = Arc<RwLock<Cst>>;

enum Msg {
    Update {
        group: String,
        request: u64,
        prev_token_count: usize,
        tokens: Vec<u32>,
    },
    Register {
        group: String,
        /// Lifetime in logical ticks (messages processed after this one).
        ttl: u64,
    },
    /// Cross-iteration warm start: preload historical streams into the
    /// group's CST (reserved request ids; see [`Cst::preload`]).
    WarmStart {
        group: String,
        streams: Vec<Vec<u32>>,
    },
    Fetch {
        groups: Vec<String>,
        reply: Sender<Vec<(String, GroupHandle)>>,
    },
    /// Test/ops hook: wait until all previously sent updates are applied.
    Flush {
        reply: Sender<()>,
    },
    Shutdown,
}

/// The DGDS master: owns group CSTs, applies updates asynchronously.
pub struct DraftServer {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

impl DraftServer {
    /// Default group lifetime for implicitly-created groups, in logical
    /// ticks (one tick = one server message). Effectively unbounded for
    /// a single rollout while still being a finite, deterministic
    /// horizon.
    pub const DEFAULT_TTL_TICKS: u64 = 1 << 32;

    pub fn spawn() -> Self {
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::Builder::new()
            .name("dgds-master".into())
            .spawn(move || Self::serve(rx))
            .expect("spawn dgds master");
        DraftServer {
            tx,
            handle: Some(handle),
        }
    }

    fn serve(rx: Receiver<Msg>) {
        struct Entry {
            cst: GroupHandle,
            /// Logical tick after which the group is pruned.
            expires: u64,
        }
        /// Live entry for an update-like message: an unknown group is
        /// created implicitly with the default TTL, but an *expired*
        /// group (tombstoned) is NOT resurrected — the caller must
        /// re-register it explicitly.
        fn live_or_new<'a>(
            groups: &'a mut BTreeMap<String, Entry>,
            expired: &BTreeSet<String>,
            group: String,
            tick: u64,
        ) -> Option<&'a mut Entry> {
            if expired.contains(&group) {
                return None;
            }
            Some(groups.entry(group).or_insert_with(|| Entry {
                cst: Arc::new(RwLock::new(Cst::new())),
                expires: tick.saturating_add(DraftServer::DEFAULT_TTL_TICKS),
            }))
        }
        let mut groups: BTreeMap<String, Entry> = BTreeMap::new();
        let mut expired: BTreeSet<String> = BTreeSet::new();
        // Logical clock: one tick per message processed. Host wall time
        // never enters lifetime decisions, so group expiry replays
        // identically for an identical message sequence.
        let mut tick: u64 = 0;
        while let Ok(msg) = rx.recv() {
            tick += 1;
            // Opportunistic TTL pruning; pruned groups leave tombstones.
            groups.retain(|g, e| {
                let live = e.expires > tick;
                if !live {
                    expired.insert(g.clone());
                }
                live
            });
            match msg {
                Msg::Update {
                    group,
                    request,
                    prev_token_count,
                    tokens,
                } => {
                    if let Some(e) =
                        live_or_new(&mut groups, &expired, group, tick)
                    {
                        e.cst
                            .write()
                            .expect("cst lock poisoned")
                            .append(request, prev_token_count, &tokens);
                    }
                }
                Msg::WarmStart { group, streams } => {
                    if let Some(e) =
                        live_or_new(&mut groups, &expired, group, tick)
                    {
                        e.cst
                            .write()
                            .expect("cst lock poisoned")
                            .preload(&streams);
                    }
                }
                Msg::Register { group, ttl } => {
                    // Explicit registration is the one path that
                    // resurrects an expired group (with a fresh CST).
                    expired.remove(&group);
                    let e = groups.entry(group).or_insert_with(|| Entry {
                        cst: Arc::new(RwLock::new(Cst::new())),
                        expires: tick.saturating_add(ttl),
                    });
                    e.expires = tick.saturating_add(ttl);
                }
                Msg::Fetch { groups: ids, reply } => {
                    let out = ids
                        .into_iter()
                        .filter_map(|g| {
                            groups.get(&g).map(|e| (g, Arc::clone(&e.cst)))
                        })
                        .collect();
                    let _ = reply.send(out);
                }
                Msg::Flush { reply } => {
                    let _ = reply.send(());
                }
                Msg::Shutdown => break,
            }
        }
    }

    /// Asynchronous append (never blocks on tree maintenance).
    pub fn update_cst(
        &self,
        group_id: &str,
        request_id: u64,
        prev_token_count: usize,
        new_tokens: &[u32],
    ) {
        let _ = self.tx.send(Msg::Update {
            group: group_id.to_string(),
            request: request_id,
            prev_token_count,
            tokens: new_tokens.to_vec(),
        });
    }

    /// Preload last iteration's token streams into `group_id`'s CST
    /// (asynchronous, like `update_cst`): grouped SD then has reference
    /// material from the first verify step of the new iteration instead
    /// of rebuilding its corpus from scratch. Call
    /// [`flush`](Self::flush) to barrier before the first speculation.
    pub fn warm_start(&self, group_id: &str, streams: &[Vec<u32>]) {
        if streams.is_empty() {
            return;
        }
        let _ = self.tx.send(Msg::WarmStart {
            group: group_id.to_string(),
            streams: streams.to_vec(),
        });
    }

    /// Register (or explicitly resurrect) a group with a lifetime of
    /// `ttl_ticks` logical ticks — one tick per message the server
    /// processes, never wall time, so expiry is deterministic. A TTL of
    /// 0 expires the group at the very next message.
    pub fn register_group(&self, group_id: &str, ttl_ticks: u64) {
        let _ = self.tx.send(Msg::Register {
            group: group_id.to_string(),
            ttl: ttl_ticks,
        });
    }

    /// Fetch shared CST handles for `group_ids`.
    pub fn fetch_cst(&self, group_ids: &[String]) -> Vec<(String, GroupHandle)> {
        let (tx, rx) = channel();
        let _ = self.tx.send(Msg::Fetch {
            groups: group_ids.to_vec(),
            reply: tx,
        });
        rx.recv().unwrap_or_default()
    }

    /// Barrier: all updates sent before this call are applied after it
    /// returns.
    pub fn flush(&self) {
        let (tx, rx) = channel();
        let _ = self.tx.send(Msg::Flush { reply: tx });
        let _ = rx.recv();
    }
}

impl Drop for DraftServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Speculation parameters (paper Table 6 `SpeculationArgs`).
#[derive(Debug, Clone, Copy)]
pub struct SpeculationArgs {
    pub max_spec_tokens: usize,
    pub pattern_lookup_max: usize,
    pub pattern_lookup_min: usize,
    pub top_k: usize,
}

impl Default for SpeculationArgs {
    fn default() -> Self {
        SpeculationArgs {
            max_spec_tokens: 8,
            pattern_lookup_max: 24,
            pattern_lookup_min: 2,
            top_k: 1,
        }
    }
}

/// The embedded draft client: caches group handles, speculates locally.
pub struct DraftClient {
    cache: BTreeMap<String, GroupHandle>,
}

impl DraftClient {
    pub fn new() -> Self {
        DraftClient {
            cache: BTreeMap::new(),
        }
    }

    /// Periodic fetch: refresh local handles for `group_ids`.
    pub fn fetch(&mut self, server: &DraftServer, group_ids: &[String]) {
        for (g, h) in server.fetch_cst(group_ids) {
            self.cache.insert(g, h);
        }
    }

    pub fn has_group(&self, group_id: &str) -> bool {
        self.cache.contains_key(group_id)
    }

    /// Speculate draft tokens for a batch of requests. Returns, per
    /// request, the ranked candidate paths (empty when the group is
    /// unknown or the pattern match is too weak).
    pub fn batch_speculate(
        &self,
        requests: &[(&str, &[u32], SpeculationArgs)],
    ) -> Vec<Vec<DraftPath>> {
        requests
            .iter()
            .map(|(group, pattern, args)| {
                let Some(handle) = self.cache.get(*group) else {
                    return vec![];
                };
                let cst = handle.read().expect("cst lock poisoned");
                speculate_multipath(
                    &cst,
                    pattern,
                    args.max_spec_tokens,
                    args.pattern_lookup_max,
                    args.pattern_lookup_min,
                    args.top_k,
                    0.0,
                )
            })
            .collect()
    }
}

impl Default for DraftClient {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_update_fetch_speculate() {
        let server = DraftServer::spawn();
        server.register_group("g0", 60);
        server.update_cst("g0", 0, 0, &[1, 2, 3, 4, 5]);
        server.update_cst("g0", 1, 0, &[9, 2, 3, 4, 7]);
        server.flush();

        let mut client = DraftClient::new();
        client.fetch(&server, &["g0".to_string()]);
        assert!(client.has_group("g0"));
        let drafts = client.batch_speculate(&[(
            "g0",
            &[0, 2, 3][..],
            SpeculationArgs::default(),
        )]);
        assert_eq!(drafts.len(), 1);
        assert!(!drafts[0].is_empty());
        assert_eq!(drafts[0][0].tokens[0], 4);
    }

    #[test]
    fn warm_start_speculates_from_history_alone() {
        let server = DraftServer::spawn();
        // No live tokens at all — only last epoch's streams.
        server.warm_start(
            "g0",
            &[vec![1, 2, 3, 4, 5], vec![9, 2, 3, 4, 7]],
        );
        server.flush();
        let mut client = DraftClient::new();
        client.fetch(&server, &["g0".to_string()]);
        let drafts = client.batch_speculate(&[(
            "g0",
            &[0, 2, 3][..],
            SpeculationArgs::default(),
        )]);
        assert_eq!(drafts.len(), 1);
        assert!(!drafts[0].is_empty(), "history must ground drafts");
        assert_eq!(drafts[0][0].tokens[0], 4);
        // Live updates coexist with warm history.
        server.update_cst("g0", 0, 0, &[2, 3, 8]);
        server.flush();
        let handles = server.fetch_cst(&["g0".to_string()]);
        let cst = handles[0].1.read().unwrap();
        assert_eq!(cst.history_streams(), 2);
        assert!(cst.contains(&[3, 8]));
        cst.check_invariants();
    }

    #[test]
    fn unknown_group_yields_no_draft() {
        let server = DraftServer::spawn();
        let mut client = DraftClient::new();
        client.fetch(&server, &["nope".to_string()]);
        let drafts = client.batch_speculate(&[(
            "nope",
            &[1, 2][..],
            SpeculationArgs::default(),
        )]);
        assert_eq!(drafts, vec![vec![]]);
    }

    #[test]
    fn concurrent_producers() {
        let server = Arc::new(DraftServer::spawn());
        server.register_group("g", 60);
        let mut joins = vec![];
        for r in 0..4u64 {
            let s = Arc::clone(&server);
            joins.push(std::thread::spawn(move || {
                // Each producer streams its own tokens in batches.
                let tokens: Vec<u32> =
                    (0..200).map(|i| ((i + r as u32) % 17) + 1).collect();
                for chunk_start in (0..tokens.len()).step_by(16) {
                    let end = (chunk_start + 16).min(tokens.len());
                    s.update_cst("g", r, chunk_start, &tokens[chunk_start..end]);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        server.flush();
        let handles = server.fetch_cst(&["g".to_string()]);
        let cst = handles[0].1.read().unwrap();
        assert_eq!(cst.total_tokens(), 4 * 200);
        cst.check_invariants();
    }

    #[test]
    fn ttl_expires_groups() {
        let server = DraftServer::spawn();
        server.register_group("ephemeral", 0); // expires at the next message
        // No sleeps: expiry is a pure function of the message sequence.
        server.register_group("other", 1 << 20);
        server.flush();
        let got = server.fetch_cst(&["ephemeral".to_string()]);
        assert!(got.is_empty());
        assert_eq!(server.fetch_cst(&["other".to_string()]).len(), 1);
    }

    #[test]
    fn expired_group_needs_explicit_reregistration() {
        let server = DraftServer::spawn();
        // tick 1: register with a 2-tick lifetime (expires after tick 3).
        server.register_group("g", 2);
        // tick 2: still live — the append applies.
        server.update_cst("g", 0, 0, &[1, 2, 3, 4]);
        // tick 3: prune runs first, the group is gone and tombstoned.
        server.flush();
        assert!(server.fetch_cst(&["g".to_string()]).is_empty());
        // A late update must NOT silently resurrect the expired group.
        server.update_cst("g", 0, 4, &[5, 6]);
        server.flush();
        assert!(server.fetch_cst(&["g".to_string()]).is_empty());
        // Explicit re-registration does — with a fresh CST.
        server.register_group("g", 1 << 20);
        server.update_cst("g", 1, 0, &[7, 8]);
        server.flush();
        let got = server.fetch_cst(&["g".to_string()]);
        assert_eq!(got.len(), 1);
        let cst = got[0].1.read().unwrap();
        assert!(cst.contains(&[7, 8]));
        assert!(!cst.contains(&[1, 2]), "expired tree must not survive");
    }

    #[test]
    fn updates_do_not_block_clients() {
        // Client speculation proceeds while a large update streams in.
        let server = DraftServer::spawn();
        server.update_cst("g", 0, 0, &[1, 2, 3, 4]);
        server.flush();
        let mut client = DraftClient::new();
        client.fetch(&server, &["g".to_string()]);
        // Fire a large async update; don't flush.
        let big: Vec<u32> = (0..50_000).map(|i| i % 97).collect();
        server.update_cst("g", 1, 0, &big);
        // Speculation still answers from the shared handle.
        let drafts = client.batch_speculate(&[(
            "g",
            &[1, 2][..],
            SpeculationArgs::default(),
        )]);
        assert_eq!(drafts.len(), 1);
        server.flush();
    }
}
