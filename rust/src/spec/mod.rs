//! Speculative decoding stack (paper §3.4).
//!
//! * [`cst`] — the compressed-suffix-tree draft structure (implemented as
//!   a generalized suffix automaton with occurrence counts: same O(p+s)
//!   query bound, O(1) amortized online extension).
//! * [`dgds`] — the Distributed Grouped Draft Server: master/worker
//!   threads, asynchronous `update_cst` appends, periodic `fetch_cst`
//!   snapshot distribution, `batch_speculate` on the client.
//! * [`mba`] — Marginal-Benefit-Aware adaptive speculation (paper Alg. 1).
//! * [`multipath`] — beam/multi-path draft candidate generation on the CST.
//! * [`simmodel`] — acceptance/draft-cost profiles of each SD strategy for
//!   the cluster simulator (grouped CST, vanilla SuffixDecoding, separate
//!   draft model, MTP), calibrated against Table 2 / Figure 11.

pub mod cst;
pub mod dgds;
pub mod mba;
pub mod multipath;
pub mod simmodel;

pub use cst::Cst;
pub use dgds::{DraftClient, DraftServer};
pub use mba::{mba_allocate, MbaInputs};
pub use simmodel::{SdStrategy, SpecSim};
