//! The grouped draft structure: a generalized suffix automaton with
//! occurrence counts over all token streams of one GRPO group.
//!
//! The paper calls this a Compressed Suffix Tree (CST); a suffix automaton
//! is the deterministic-automaton dual with the same asymptotics — O(1)
//! amortized online extension per token and O(p + s) drafting (walk the
//! p-token pattern, then emit s draft tokens by following transitions).
//! Occurrence counts propagate along the suffix-link chain at append time,
//! giving the per-transition frequencies that score draft candidates
//! (SuffixDecoding-style confidence).

use std::collections::BTreeMap;

const ROOT: u32 = 0;

#[derive(Debug, Clone, Default)]
struct State {
    len: u32,
    link: i32,
    next: BTreeMap<u32, u32>,
    /// Occurrence weight (endpos-count approximation maintained online).
    cnt: u64,
}

/// Per-request extension cursor.
#[derive(Debug, Clone, Copy)]
struct Cursor {
    state: u32,
    /// Tokens appended by this request (for idempotent appends).
    appended: usize,
}

/// Reserved request-id base for [`Cst::preload`] streams; live request
/// ids stay far below it.
const HISTORY_REQ_BASE: u64 = 1 << 48;

/// Generalized suffix automaton over a group's token streams.
#[derive(Debug, Default)]
pub struct Cst {
    states: Vec<State>,
    cursors: BTreeMap<u64, Cursor>,
    total_tokens: u64,
    /// Count of historical streams ingested via [`Cst::preload`].
    history_streams: u64,
}

impl Cst {
    pub fn new() -> Self {
        Cst {
            states: vec![State {
                len: 0,
                link: -1,
                next: BTreeMap::new(),
                cnt: 0,
            }],
            cursors: BTreeMap::new(),
            total_tokens: 0,
            history_streams: 0,
        }
    }

    pub fn n_states(&self) -> usize {
        self.states.len()
    }

    pub fn total_tokens(&self) -> u64 {
        self.total_tokens
    }

    /// Append tokens from request `req`, continuing its stream.
    /// `prev_token_count` makes the call idempotent (the DGDS
    /// `update_cst` API): tokens already seen from this request are
    /// skipped.
    pub fn append(&mut self, req: u64, prev_token_count: usize, tokens: &[u32]) {
        let mut cur = self
            .cursors
            .get(&req)
            .copied()
            .unwrap_or(Cursor { state: ROOT, appended: 0 });
        debug_assert!(
            prev_token_count <= cur.appended,
            "gap in request stream: have {} tokens, update starts at {}",
            cur.appended,
            prev_token_count
        );
        let skip = cur.appended - prev_token_count;
        for &t in tokens.iter().skip(skip) {
            cur.state = self.extend(cur.state, t);
            cur.appended += 1;
            self.total_tokens += 1;
            self.bump_counts(cur.state);
        }
        self.cursors.insert(req, cur);
    }

    /// Preload historical token streams (cross-iteration warm start):
    /// each stream is appended under a reserved request id so it can
    /// never collide with — or be extended by — a live request's
    /// idempotent-append cursor. Drafting then has reference material
    /// from the first lookup, before any live sibling produces tokens.
    pub fn preload(&mut self, streams: &[Vec<u32>]) {
        for (i, s) in streams.iter().enumerate() {
            let id = HISTORY_REQ_BASE + self.history_streams + i as u64;
            self.append(id, 0, s);
        }
        self.history_streams += streams.len() as u64;
    }

    /// Streams ingested through [`preload`](Self::preload).
    pub fn history_streams(&self) -> u64 {
        self.history_streams
    }

    /// Generalized SAM extension from state `last` with token `c`.
    fn extend(&mut self, last: u32, c: u32) -> u32 {
        // Pre-existing transition (common in generalized SAMs).
        if let Some(&q) = self.states[last as usize].next.get(&c) {
            if self.states[q as usize].len == self.states[last as usize].len + 1
            {
                return q;
            }
            return self.clone_state(last, q, c);
        }
        let cur = self.states.len() as u32;
        self.states.push(State {
            len: self.states[last as usize].len + 1,
            link: 0,
            next: BTreeMap::new(),
            cnt: 0,
        });
        let mut p = last as i32;
        while p >= 0 && !self.states[p as usize].next.contains_key(&c) {
            self.states[p as usize].next.insert(c, cur);
            p = self.states[p as usize].link;
        }
        if p == -1 {
            self.states[cur as usize].link = ROOT as i32;
            return cur;
        }
        let q = self.states[p as usize].next[&c];
        if self.states[q as usize].len == self.states[p as usize].len + 1 {
            self.states[cur as usize].link = q as i32;
            return cur;
        }
        let clone = self.clone_state(p as u32, q, c);
        self.states[cur as usize].link = clone as i32;
        cur
    }

    fn clone_state(&mut self, p: u32, q: u32, c: u32) -> u32 {
        let clone = self.states.len() as u32;
        let mut st = self.states[q as usize].clone();
        st.len = self.states[p as usize].len + 1;
        // The clone inherits q's occurrence weight: it represents the
        // same right contexts for the shorter substrings.
        self.states.push(st);
        let mut pp = p as i32;
        while pp >= 0
            && self.states[pp as usize].next.get(&c) == Some(&q)
        {
            self.states[pp as usize].next.insert(c, clone);
            pp = self.states[pp as usize].link;
        }
        self.states[q as usize].link = clone as i32;
        clone
    }

    /// Propagate an occurrence along the suffix-link chain.
    fn bump_counts(&mut self, mut s: u32) {
        loop {
            self.states[s as usize].cnt += 1;
            let link = self.states[s as usize].link;
            if link <= 0 {
                if link == 0 {
                    // root also counts total positions; harmless.
                    self.states[0].cnt += 1;
                }
                break;
            }
            s = link as u32;
        }
    }

    /// Match the longest suffix of `pattern` present in the corpus.
    /// Returns (state, matched length).
    pub fn match_suffix(&self, pattern: &[u32]) -> (u32, usize) {
        let mut state = ROOT;
        let mut length = 0usize;
        for &c in pattern {
            loop {
                if let Some(&nxt) = self.states[state as usize].next.get(&c) {
                    state = nxt;
                    length += 1;
                    break;
                }
                let link = self.states[state as usize].link;
                if link < 0 {
                    length = 0;
                    break;
                }
                state = link as u32;
                length = self.states[state as usize].len as usize;
                if state == ROOT && self.states[ROOT as usize].next.get(&c).is_none()
                {
                    break;
                }
            }
        }
        (state, length)
    }

    /// Outgoing transitions of `state` with target occurrence counts.
    pub fn transitions(&self, state: u32) -> impl Iterator<Item = (u32, u32, u64)> + '_ {
        self.states[state as usize]
            .next
            .iter()
            .map(move |(&c, &t)| (c, t, self.states[t as usize].cnt))
    }

    /// After a suffix match, the matched state is often the tail of the
    /// *current* stream itself (the CST contains the drafting request's
    /// own prefix) — a dead end with no outgoing transitions. Back off
    /// along suffix links to the longest matched context that has a
    /// continuation somewhere in the corpus.
    pub(crate) fn backoff_to_continuation(
        &self,
        mut state: u32,
        mut matched: usize,
        lookup_min: usize,
    ) -> Option<(u32, usize)> {
        loop {
            if matched < lookup_min {
                return None;
            }
            if !self.states[state as usize].next.is_empty() {
                return Some((state, matched));
            }
            let link = self.states[state as usize].link;
            if link < 0 {
                return None;
            }
            state = link as u32;
            matched = self.states[state as usize].len as usize;
        }
    }

    /// Linear (single-path) speculation: match the pattern's longest
    /// suffix, back off to a state with continuations, then greedily
    /// follow the highest-count transitions.
    /// Returns the draft tokens (possibly fewer than `max_tokens`).
    /// `lookup_min`: minimum matched pattern length to draft at all.
    pub fn speculate(
        &self,
        pattern: &[u32],
        max_tokens: usize,
        lookup_max: usize,
        lookup_min: usize,
    ) -> Vec<u32> {
        let start = pattern.len().saturating_sub(lookup_max);
        let (state, matched) = self.match_suffix(&pattern[start..]);
        let Some((mut state, _)) =
            self.backoff_to_continuation(state, matched, lookup_min)
        else {
            return vec![];
        };
        let mut out = Vec::with_capacity(max_tokens);
        for _ in 0..max_tokens {
            let best = self
                .states[state as usize]
                .next
                .iter()
                .max_by_key(|(&c, &t)| (self.states[t as usize].cnt, u32::MAX - c));
            match best {
                Some((&c, &t)) => {
                    out.push(c);
                    state = t;
                }
                None => break,
            }
        }
        out
    }

    /// Occurrence count of the exact state reached by the longest suffix
    /// match of `pattern` (confidence signal).
    pub fn suffix_count(&self, pattern: &[u32]) -> u64 {
        let (state, len) = self.match_suffix(pattern);
        if len == 0 {
            0
        } else {
            self.states[state as usize].cnt
        }
    }

    /// Check automaton structural invariants (tests).
    pub fn check_invariants(&self) {
        for (i, s) in self.states.iter().enumerate() {
            if i == 0 {
                assert_eq!(s.link, -1);
                assert_eq!(s.len, 0);
                continue;
            }
            let link = s.link;
            assert!(link >= 0, "non-root state without link");
            assert!(
                self.states[link as usize].len < s.len,
                "suffix link must shorten"
            );
            for (_, &t) in &s.next {
                assert!((t as usize) < self.states.len());
                assert!(self.states[t as usize].len >= s.len + 1);
            }
        }
    }

    /// Does `needle` occur as a substring of any appended stream?
    pub fn contains(&self, needle: &[u32]) -> bool {
        let mut state = ROOT;
        for &c in needle {
            match self.states[state as usize].next.get(&c) {
                Some(&t) => state = t,
                None => return false,
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;
    use crate::util::prop::{check, PropConfig};

    fn brute_contains(streams: &[Vec<u32>], needle: &[u32]) -> bool {
        streams.iter().any(|s| {
            s.windows(needle.len()).any(|w| w == needle)
        })
    }

    #[test]
    fn single_stream_substrings() {
        let mut cst = Cst::new();
        let s = vec![1, 2, 3, 1, 2, 4];
        cst.append(0, 0, &s);
        cst.check_invariants();
        assert!(cst.contains(&[1, 2, 3]));
        assert!(cst.contains(&[2, 3, 1, 2, 4]));
        assert!(cst.contains(&[4]));
        assert!(!cst.contains(&[3, 2]));
        assert!(!cst.contains(&[1, 2, 5]));
    }

    #[test]
    fn multi_stream_substrings() {
        let mut cst = Cst::new();
        cst.append(0, 0, &[1, 2, 3]);
        cst.append(1, 0, &[3, 4, 5]);
        cst.check_invariants();
        assert!(cst.contains(&[1, 2, 3]));
        assert!(cst.contains(&[4, 5]));
        // Cross-stream substrings must NOT exist.
        assert!(!cst.contains(&[2, 3, 3]));
        assert!(!cst.contains(&[3, 3]));
    }

    #[test]
    fn incremental_append_equals_batch() {
        let mut a = Cst::new();
        let mut b = Cst::new();
        let s: Vec<u32> = vec![5, 6, 5, 6, 7, 5, 6, 5];
        a.append(0, 0, &s);
        for (i, &t) in s.iter().enumerate() {
            b.append(0, i, &[t]);
        }
        for w in 1..=s.len() {
            for win in s.windows(w) {
                assert_eq!(a.contains(win), b.contains(win));
            }
        }
    }

    #[test]
    fn idempotent_appends() {
        let mut cst = Cst::new();
        cst.append(0, 0, &[1, 2, 3, 4]);
        let states = cst.n_states();
        let tokens = cst.total_tokens();
        // Overlapping re-delivery (DGDS at-least-once semantics).
        cst.append(0, 2, &[3, 4, 5]);
        assert_eq!(cst.total_tokens(), tokens + 1);
        assert!(cst.contains(&[3, 4, 5]));
        assert!(cst.n_states() >= states);
        cst.check_invariants();
    }

    #[test]
    fn preload_grounds_speculation_before_any_live_tokens() {
        let mut cst = Cst::new();
        // Last epoch's sibling streams share the [10, 11, 12, 13] motif.
        cst.preload(&[vec![1, 10, 11, 12, 13, 2], vec![3, 10, 11, 12, 13, 4]]);
        cst.check_invariants();
        assert_eq!(cst.history_streams(), 2);
        // A fresh live request drafts from history alone.
        let draft = cst.speculate(&[9, 10, 11], 2, 8, 2);
        assert_eq!(draft, vec![12, 13]);
        // Live appends continue to work alongside preloaded history,
        // including a live request id that starts from zero.
        cst.append(0, 0, &[10, 11, 12, 5]);
        cst.check_invariants();
        assert!(cst.contains(&[12, 5]));
        assert!(cst.contains(&[12, 13]));
        // A second preload batch keeps reserved ids distinct.
        cst.preload(&[vec![7, 7, 7]]);
        assert_eq!(cst.history_streams(), 3);
        assert!(cst.contains(&[7, 7, 7]));
    }

    #[test]
    fn speculate_returns_corpus_continuation() {
        let mut cst = Cst::new();
        // Two siblings share the pattern [10, 11, 12, 13, 14].
        cst.append(0, 0, &[1, 10, 11, 12, 13, 14, 2]);
        cst.append(1, 0, &[3, 10, 11, 12, 13, 14, 4]);
        let draft = cst.speculate(&[9, 9, 10, 11], 3, 8, 2);
        assert_eq!(draft, vec![12, 13, 14]);
    }

    #[test]
    fn speculate_respects_lookup_min() {
        let mut cst = Cst::new();
        cst.append(0, 0, &[1, 2, 3, 4, 5]);
        // Pattern tail matches only 1 token; lookup_min 2 forbids drafting.
        let draft = cst.speculate(&[9, 9, 1], 3, 8, 2);
        assert!(draft.is_empty());
    }

    #[test]
    fn counts_prefer_frequent_continuation() {
        let mut cst = Cst::new();
        // After [7, 8]: token 1 occurs 3x, token 2 occurs once.
        cst.append(0, 0, &[7, 8, 1, 7, 8, 1, 7, 8, 1, 7, 8, 2]);
        let draft = cst.speculate(&[7, 8], 1, 8, 1);
        assert_eq!(draft, vec![1]);
    }

    #[test]
    fn match_suffix_finds_longest() {
        let mut cst = Cst::new();
        cst.append(0, 0, &[1, 2, 3, 4, 5, 6]);
        let (_, len) = cst.match_suffix(&[9, 9, 3, 4, 5]);
        assert_eq!(len, 3);
        let (_, len) = cst.match_suffix(&[9, 9, 9]);
        assert_eq!(len, 0);
    }

    #[test]
    fn prop_contains_matches_bruteforce() {
        check(
            "sam contains == brute force",
            PropConfig {
                cases: 40,
                max_size: 60,
                ..Default::default()
            },
            |c| {
                let n_streams = c.rng.range_usize(1, 3);
                let mut cst = Cst::new();
                let mut streams = vec![];
                for r in 0..n_streams {
                    let len = c.rng.range_usize(1, c.size.max(2));
                    let s: Vec<u32> =
                        (0..len).map(|_| c.rng.below(5) as u32).collect();
                    cst.append(r as u64, 0, &s);
                    streams.push(s);
                }
                cst.check_invariants();
                // Probe random windows and random non-windows.
                for _ in 0..30 {
                    let si = c.rng.range_usize(0, streams.len() - 1);
                    let s = &streams[si];
                    let a = c.rng.range_usize(0, s.len() - 1);
                    let b = c.rng.range_usize(a + 1, s.len());
                    assert!(
                        cst.contains(&s[a..b]),
                        "missing window {:?}",
                        &s[a..b]
                    );
                    let probe: Vec<u32> = (0..c.rng.range_usize(1, 6))
                        .map(|_| c.rng.below(6) as u32)
                        .collect();
                    assert_eq!(
                        cst.contains(&probe),
                        brute_contains(&streams, &probe),
                        "probe {probe:?}"
                    );
                }
            },
        );
    }

    #[test]
    fn prop_speculation_is_corpus_substring() {
        check(
            "speculation output extends a corpus match",
            PropConfig {
                cases: 30,
                max_size: 80,
                ..Default::default()
            },
            |c| {
                let mut cst = Cst::new();
                let mut streams = vec![];
                for r in 0..2 {
                    let len = c.rng.range_usize(8, c.size.max(9));
                    let s: Vec<u32> =
                        (0..len).map(|_| c.rng.below(4) as u32).collect();
                    cst.append(r, 0, &s);
                    streams.push(s);
                }
                let si = c.rng.range_usize(0, 1);
                let s = &streams[si];
                let cut = c.rng.range_usize(2, s.len() - 1);
                let pattern = &s[..cut];
                let draft = cst.speculate(pattern, 4, 6, 1);
                if draft.is_empty() {
                    return;
                }
                // The matched suffix + draft must be a substring of some
                // stream: find the longest matched suffix first.
                let start = pattern.len().saturating_sub(6);
                let (_, matched) = cst.match_suffix(&pattern[start..]);
                let mut probe: Vec<u32> =
                    pattern[pattern.len() - matched..].to_vec();
                probe.extend_from_slice(&draft);
                assert!(
                    brute_contains(&streams, &probe),
                    "draft {draft:?} not grounded (probe {probe:?})"
                );
            },
        );
    }

    #[test]
    fn linear_state_growth() {
        // SAM has at most 2n-1 states — the "compressed" guarantee.
        let mut cst = Cst::new();
        let mut rng = Rng::new(3);
        let s: Vec<u32> = (0..2000).map(|_| rng.below(8) as u32).collect();
        cst.append(0, 0, &s);
        assert!(cst.n_states() <= 2 * s.len());
    }
}
