//! Mooncake-derived global KVCache pool (paper §3.2).
//!
//! When divided rollout pauses a request between chunks or migrates it to
//! another instance, its KVCache moves into a hierarchical global store
//! (DRAM tier, spilling to SSD) instead of being recomputed. Fetching it
//! back onto an instance costs transfer time (RDMA bandwidth + latency,
//! plus SSD read if spilled) — orders of magnitude cheaper than the
//! re-prefill a preemption-based system pays.
//!
//! The pool models capacity and transfer cost; actual KV bytes live on the
//! engine side (simulation) or in PJRT buffers (real-model path).

use std::collections::BTreeMap;

use crate::config::HardwareConfig;
use crate::sim::clock::SimTime;
use crate::workload::RequestId;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    Dram,
    Ssd,
}

#[derive(Debug, Clone, Copy)]
struct Entry {
    bytes: u64,
    tier: Tier,
    /// Insertion order for FIFO spill (proxy for LRU: paused requests are
    /// not re-read until rescheduled).
    seq: u64,
}

/// Aggregate pool statistics, sampled by the metrics timeline.
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    pub dram_bytes: u64,
    pub ssd_bytes: u64,
    pub entries: usize,
    pub spills: u64,
    pub fetches: u64,
    pub stores: u64,
}

#[derive(Debug)]
pub struct GlobalKvPool {
    dram_capacity: u64,
    ssd_capacity: u64,
    rdma_bw: f64,
    rdma_latency: SimTime,
    ssd_bw: f64,
    entries: BTreeMap<RequestId, Entry>,
    dram_used: u64,
    ssd_used: u64,
    next_seq: u64,
    stats: PoolStats,
}

impl GlobalKvPool {
    /// Build from hardware config; capacities aggregate over `n_nodes`.
    pub fn new(hw: &HardwareConfig, n_nodes: usize) -> Self {
        GlobalKvPool {
            dram_capacity: hw.pool_dram_bytes * n_nodes as u64,
            ssd_capacity: hw.pool_ssd_bytes * n_nodes as u64,
            rdma_bw: hw.rdma_bw,
            rdma_latency: hw.rdma_latency,
            ssd_bw: hw.ssd_bw,
            entries: BTreeMap::new(),
            dram_used: 0,
            ssd_used: 0,
            next_seq: 0,
            stats: PoolStats::default(),
        }
    }

    /// Store (or update) a paused request's KV. Returns the transfer time
    /// to push it over RDMA. Spills oldest DRAM entries to SSD if needed;
    /// panics if even SSD is exhausted (sized so this cannot happen for
    /// the paper workloads — an assert, not a failure mode).
    pub fn store(&mut self, id: RequestId, bytes: u64) -> SimTime {
        // Replace any previous entry (chunk boundaries re-store grown KV).
        self.remove(id);
        while self.dram_used + bytes > self.dram_capacity {
            self.spill_oldest();
        }
        self.dram_used += bytes;
        self.entries.insert(
            id,
            Entry {
                bytes,
                tier: Tier::Dram,
                seq: self.next_seq,
            },
        );
        self.next_seq += 1;
        self.stats.stores += 1;
        self.transfer_time(bytes, Tier::Dram)
    }

    /// Fetch a request's KV onto an instance. Returns Some(transfer time)
    /// and removes the entry; None if the pool never had it (request's
    /// first chunk, nothing to fetch).
    pub fn fetch(&mut self, id: RequestId) -> Option<SimTime> {
        let e = self.entries.get(&id).copied()?;
        self.remove(id);
        self.stats.fetches += 1;
        Some(self.transfer_time(e.bytes, e.tier))
    }

    /// Tier the request currently sits in (None if absent).
    pub fn tier_of(&self, id: RequestId) -> Option<Tier> {
        self.entries.get(&id).map(|e| e.tier)
    }

    pub fn holds(&self, id: RequestId) -> bool {
        self.entries.contains_key(&id)
    }

    /// Drop a request's KV (finished or aborted).
    pub fn remove(&mut self, id: RequestId) {
        if let Some(e) = self.entries.remove(&id) {
            match e.tier {
                Tier::Dram => self.dram_used -= e.bytes,
                Tier::Ssd => self.ssd_used -= e.bytes,
            }
        }
    }

    fn spill_oldest(&mut self) {
        let oldest = self
            .entries
            .iter()
            .filter(|(_, e)| e.tier == Tier::Dram)
            .min_by_key(|(_, e)| e.seq)
            .map(|(id, _)| *id)
            .expect("DRAM over capacity but nothing to spill");
        let e = self.entries.get_mut(&oldest).unwrap();
        assert!(
            self.ssd_used + e.bytes <= self.ssd_capacity,
            "global KV pool exhausted (SSD tier full)"
        );
        self.dram_used -= e.bytes;
        self.ssd_used += e.bytes;
        e.tier = Tier::Ssd;
        self.stats.spills += 1;
    }

    fn transfer_time(&self, bytes: u64, tier: Tier) -> SimTime {
        let rdma = bytes as f64 / self.rdma_bw;
        let extra = match tier {
            Tier::Dram => 0.0,
            Tier::Ssd => bytes as f64 / self.ssd_bw,
        };
        self.rdma_latency + SimTime::from_secs_f64(rdma + extra)
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            dram_bytes: self.dram_used,
            ssd_bytes: self.ssd_used,
            entries: self.entries.len(),
            ..self.stats
        }
    }

    pub fn check_invariants(&self) {
        let (mut dram, mut ssd) = (0u64, 0u64);
        for e in self.entries.values() {
            match e.tier {
                Tier::Dram => dram += e.bytes,
                Tier::Ssd => ssd += e.bytes,
            }
        }
        assert_eq!(dram, self.dram_used, "dram accounting drift");
        assert_eq!(ssd, self.ssd_used, "ssd accounting drift");
        assert!(self.dram_used <= self.dram_capacity);
        assert!(self.ssd_used <= self.ssd_capacity);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskPreset;
    use crate::util::prop::{check, PropConfig};

    fn pool(dram: u64, ssd: u64) -> GlobalKvPool {
        let mut hw = TaskPreset::Moonlight.workload().hw;
        hw.pool_dram_bytes = dram;
        hw.pool_ssd_bytes = ssd;
        GlobalKvPool::new(&hw, 1)
    }

    fn rid(i: u32) -> RequestId {
        RequestId(i)
    }

    #[test]
    fn store_fetch_roundtrip() {
        let mut p = pool(1000, 1000);
        let t_store = p.store(rid(1), 500);
        assert!(t_store > SimTime::ZERO);
        assert!(p.holds(rid(1)));
        let t_fetch = p.fetch(rid(1)).unwrap();
        assert!(t_fetch >= t_store); // same bytes, same tier
        assert!(!p.holds(rid(1)));
        assert!(p.fetch(rid(1)).is_none());
    }

    #[test]
    fn spills_to_ssd_in_fifo_order() {
        let mut p = pool(1000, 10_000);
        p.store(rid(1), 600);
        p.store(rid(2), 600); // forces rid(1) to SSD
        assert_eq!(p.tier_of(rid(1)), Some(Tier::Ssd));
        assert_eq!(p.tier_of(rid(2)), Some(Tier::Dram));
        assert_eq!(p.stats().spills, 1);
    }

    #[test]
    fn ssd_fetch_slower_than_dram() {
        // GB-scale entries so the bandwidth terms dominate the fixed
        // RDMA latency (µs resolution).
        let gb = 1u64 << 30;
        let mut p = pool(gb, 10 * gb);
        p.store(rid(1), gb * 3 / 4);
        p.store(rid(2), gb * 3 / 4); // rid(1) spilled
        let t_ssd = p.fetch(rid(1)).unwrap();
        let t_dram = p.fetch(rid(2)).unwrap();
        assert!(t_ssd > t_dram, "{t_ssd:?} vs {t_dram:?}");
    }

    #[test]
    fn restore_replaces_entry() {
        let mut p = pool(10_000, 10_000);
        p.store(rid(1), 100);
        p.store(rid(1), 900); // grown KV at next chunk boundary
        assert_eq!(p.stats().dram_bytes, 900);
        assert_eq!(p.stats().entries, 1);
    }

    #[test]
    #[should_panic(expected = "exhausted")]
    fn panics_when_both_tiers_full() {
        let mut p = pool(100, 100);
        p.store(rid(1), 90);
        p.store(rid(2), 90);
        p.store(rid(3), 90); // dram full, ssd full -> panic
    }

    #[test]
    fn prop_pool_accounting() {
        check(
            "global pool accounting",
            PropConfig {
                cases: 48,
                max_size: 150,
                ..Default::default()
            },
            |c| {
                let mut p = pool(50_000, 500_000);
                let mut live: Vec<u32> = vec![];
                for step in 0..c.size {
                    match c.rng.below(4) {
                        0 | 1 => {
                            let id = step as u32;
                            let bytes = c.rng.range_u64(100, 2000);
                            p.store(rid(id), bytes);
                            live.push(id);
                        }
                        2 if !live.is_empty() => {
                            let i = c.rng.range_usize(0, live.len() - 1);
                            let _ = p.fetch(rid(live.swap_remove(i)));
                        }
                        _ if !live.is_empty() => {
                            let i = c.rng.range_usize(0, live.len() - 1);
                            p.remove(rid(live.swap_remove(i)));
                        }
                        _ => {}
                    }
                    p.check_invariants();
                }
            },
        );
    }
}
