//! Per-instance paged KV allocator.
//!
//! Tokens are stored in fixed-size blocks (vLLM's PagedAttention layout);
//! the allocator tracks per-request block counts and enforces the
//! instance's capacity. The engine asks it two questions: "does request r
//! fit if it grows by n tokens?" and "how many tokens of headroom remain?".

use std::collections::BTreeMap;

use crate::workload::RequestId;

#[derive(Debug, Clone)]
pub struct PagedAllocator {
    /// Block size in tokens.
    block_tokens: u32,
    /// Total capacity in blocks.
    capacity_blocks: u64,
    used_blocks: u64,
    /// Per-request (blocks, tokens) accounting.
    requests: BTreeMap<RequestId, ReqAlloc>,
}

#[derive(Debug, Clone, Copy, Default)]
struct ReqAlloc {
    blocks: u64,
    tokens: u64,
}

impl PagedAllocator {
    pub fn new(capacity_tokens: u64, block_tokens: u32) -> Self {
        assert!(block_tokens > 0);
        PagedAllocator {
            block_tokens,
            capacity_blocks: capacity_tokens / block_tokens as u64,
            used_blocks: 0,
            requests: BTreeMap::new(),
        }
    }

    fn blocks_for(&self, tokens: u64) -> u64 {
        tokens.div_ceil(self.block_tokens as u64)
    }

    /// Grow request `id` by `tokens`. Returns false (and changes nothing)
    /// if capacity would be exceeded.
    pub fn grow(&mut self, id: RequestId, tokens: u64) -> bool {
        let cur = self.requests.get(&id).copied().unwrap_or_default();
        let new_blocks = self.blocks_for(cur.tokens + tokens);
        let delta = new_blocks - cur.blocks;
        if self.used_blocks + delta > self.capacity_blocks {
            return false;
        }
        self.used_blocks += delta;
        self.requests.insert(
            id,
            ReqAlloc {
                blocks: new_blocks,
                tokens: cur.tokens + tokens,
            },
        );
        true
    }

    /// Grow request `id` by *up to* `tokens`, clamping to what fits.
    /// Returns the granted token count (0 if nothing fits).
    pub fn grow_upto(&mut self, id: RequestId, tokens: u64) -> u64 {
        let cur = self.requests.get(&id).copied().unwrap_or_default();
        let bt = self.block_tokens as u64;
        // Room inside the request's current partial block...
        let slack = cur.blocks * bt - cur.tokens;
        // ...plus whole free blocks.
        let free_blocks = self.capacity_blocks - self.used_blocks;
        let can = slack + free_blocks * bt;
        let granted = tokens.min(can);
        if granted > 0 {
            let ok = self.grow(id, granted);
            debug_assert!(ok, "grow_upto internal miscount");
        }
        granted
    }

    /// Whether growing `id` by `tokens` would fit.
    pub fn fits(&self, id: RequestId, tokens: u64) -> bool {
        let cur = self.requests.get(&id).copied().unwrap_or_default();
        let delta = self.blocks_for(cur.tokens + tokens) - cur.blocks;
        self.used_blocks + delta <= self.capacity_blocks
    }

    /// Release all of `id`'s blocks (request finished, migrated away, or
    /// preempted). Returns the freed token count.
    pub fn release(&mut self, id: RequestId) -> u64 {
        if let Some(a) = self.requests.remove(&id) {
            debug_assert!(self.used_blocks >= a.blocks);
            self.used_blocks -= a.blocks;
            a.tokens
        } else {
            0
        }
    }

    pub fn tokens_of(&self, id: RequestId) -> u64 {
        self.requests.get(&id).map(|a| a.tokens).unwrap_or(0)
    }

    pub fn holds(&self, id: RequestId) -> bool {
        self.requests.contains_key(&id)
    }

    pub fn used_blocks(&self) -> u64 {
        self.used_blocks
    }

    pub fn block_tokens(&self) -> u32 {
        self.block_tokens
    }

    /// Tokens actually consumed including block rounding.
    pub fn used_block_tokens(&self) -> u64 {
        self.used_blocks * self.block_tokens as u64
    }

    pub fn capacity_blocks(&self) -> u64 {
        self.capacity_blocks
    }

    pub fn used_tokens(&self) -> u64 {
        self.requests.values().map(|a| a.tokens).sum()
    }

    pub fn free_tokens(&self) -> u64 {
        (self.capacity_blocks - self.used_blocks) * self.block_tokens as u64
    }

    pub fn utilization(&self) -> f64 {
        if self.capacity_blocks == 0 {
            return 1.0;
        }
        self.used_blocks as f64 / self.capacity_blocks as f64
    }

    pub fn n_requests(&self) -> usize {
        self.requests.len()
    }

    pub fn request_ids(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.requests.keys().copied()
    }

    /// Internal-consistency check used by the invariant tests.
    pub fn check_invariants(&self) {
        let sum: u64 = self.requests.values().map(|a| a.blocks).sum();
        assert_eq!(sum, self.used_blocks, "block accounting drift");
        assert!(self.used_blocks <= self.capacity_blocks, "over capacity");
        for (id, a) in &self.requests {
            assert_eq!(
                a.blocks,
                self.blocks_for(a.tokens),
                "request {id:?} block/token mismatch"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};

    fn rid(i: u32) -> RequestId {
        RequestId(i)
    }

    #[test]
    fn grow_and_release() {
        let mut a = PagedAllocator::new(1000, 10);
        assert!(a.grow(rid(1), 25)); // 3 blocks
        assert_eq!(a.used_blocks(), 3);
        assert_eq!(a.tokens_of(rid(1)), 25);
        assert!(a.grow(rid(1), 5)); // exactly 3 blocks still
        assert_eq!(a.used_blocks(), 3);
        assert!(a.grow(rid(1), 1)); // spills into 4th block
        assert_eq!(a.used_blocks(), 4);
        assert_eq!(a.release(rid(1)), 31);
        assert_eq!(a.used_blocks(), 0);
    }

    #[test]
    fn rejects_over_capacity() {
        let mut a = PagedAllocator::new(100, 10);
        assert!(a.grow(rid(1), 95));
        assert!(!a.fits(rid(2), 10));
        assert!(!a.grow(rid(2), 10));
        assert_eq!(a.used_blocks(), 10);
        assert_eq!(a.tokens_of(rid(2)), 0);
        // Fits exactly within the last partial block of r1? No: r1 holds
        // all 10 blocks already.
        assert!(a.fits(rid(1), 5));
        assert!(a.grow(rid(1), 5));
    }

    #[test]
    fn free_tokens_matches_blocks() {
        let mut a = PagedAllocator::new(100, 10);
        a.grow(rid(1), 11);
        assert_eq!(a.used_blocks(), 2);
        assert_eq!(a.free_tokens(), 80);
        assert!((a.utilization() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn release_unknown_is_zero() {
        let mut a = PagedAllocator::new(100, 10);
        assert_eq!(a.release(rid(9)), 0);
    }

    #[test]
    fn prop_accounting_never_drifts() {
        check(
            "paged allocator accounting",
            PropConfig {
                cases: 64,
                max_size: 200,
                ..Default::default()
            },
            |c| {
                let mut a = PagedAllocator::new(10_000, 16);
                let mut live: Vec<u32> = vec![];
                for step in 0..c.size {
                    match c.rng.below(3) {
                        0 => {
                            let id = step as u32;
                            let tokens = c.rng.range_u64(1, 300);
                            if a.grow(rid(id), tokens) {
                                live.push(id);
                            }
                        }
                        1 if !live.is_empty() => {
                            let i = c.rng.range_usize(0, live.len() - 1);
                            let tokens = c.rng.range_u64(1, 200);
                            let _ = a.grow(rid(live[i]), tokens);
                        }
                        _ if !live.is_empty() => {
                            let i = c.rng.range_usize(0, live.len() - 1);
                            a.release(rid(live.swap_remove(i)));
                        }
                        _ => {}
                    }
                    a.check_invariants();
                }
            },
        );
    }
}
