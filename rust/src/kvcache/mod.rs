//! KVCache management: the paged per-instance allocator (vLLM-style) and
//! the Mooncake-derived global KVCache pool that makes divided rollout's
//! chunk-level migration cheap (paper §3.2).

pub mod paged;
pub mod pool;

pub use paged::PagedAllocator;
pub use pool::{GlobalKvPool, PoolStats, Tier};
