//! `seer` — CLI entrypoint for the rollout coordinator, experiment
//! harness, and end-to-end GRPO training.
//!
//! Subcommands:
//!   seer experiment <id|all> [--full] [--seed N] [--iters N]
//!   seer rollout --task <moonlight|qwen|kimi> --scheduler <name> [--sd <strategy>]
//!   seer train [--preset small] [--iters N] [--artifacts DIR]
//!   seer info

use anyhow::Result;
use seer::config::TaskPreset;
use seer::engine::cluster::run_rollout;
use seer::scheduler::{
    ContextMode, Scheduler, SeerScheduler, StreamRlOracle, VerlScheduler,
};
use seer::spec::simmodel::SdStrategy;
use seer::util::cli::Args;

const USAGE: &str = "\
seer — reproduction of 'Seer: Online Context Learning for Fast Synchronous \
LLM Reinforcement Learning'

USAGE:
  seer experiment <table1|table2|table3|table4|fig2|fig3|fig4|fig7|fig8|fig9|fig10|fig11|fig12|all>
       [--full] [--seed N] [--iters N]
  seer rollout --task <moonlight|qwen|kimi> [--scheduler <seer|verl|streamrl|no-context|oracle>]
       [--sd <none|grouped-cst|suffix-decoding|draft-model|mtp>] [--full] [--seed N]
  seer train [--preset tiny|small] [--iters N] [--artifacts DIR] [--spec]
  seer info
";

fn make_scheduler(name: &str) -> Result<Box<dyn Scheduler>> {
    Ok(match name {
        "seer" => Box::new(SeerScheduler::new(ContextMode::Learned)),
        "no-context" => Box::new(SeerScheduler::new(ContextMode::None)),
        "oracle" => Box::new(SeerScheduler::new(ContextMode::Oracle)),
        "verl" => Box::new(VerlScheduler::new()),
        "streamrl" => Box::new(StreamRlOracle::new()),
        other => anyhow::bail!("unknown scheduler '{other}'"),
    })
}

fn make_sd(name: &str) -> Result<SdStrategy> {
    Ok(match name {
        "none" => SdStrategy::None,
        "grouped-cst" => SdStrategy::GroupedCst,
        "suffix-decoding" => SdStrategy::SuffixDecoding,
        "draft-model" => SdStrategy::DraftModel,
        "mtp" => SdStrategy::Mtp,
        other => anyhow::bail!("unknown SD strategy '{other}'"),
    })
}

fn cmd_rollout(args: &Args) -> Result<()> {
    let preset = TaskPreset::from_name(args.get_or("task", "moonlight"))
        .ok_or_else(|| anyhow::anyhow!("unknown --task"))?;
    let scale = seer::experiments::common::Scale::from_args(
        !args.has_flag("full"),
        args,
    );
    let cfg = scale.workload(preset);
    let sys = scale.sys(&cfg);
    let sched = make_scheduler(args.get_or("scheduler", "seer"))?;
    let sd = make_sd(args.get_or("sd", "grouped-cst"))?;
    let name = sched.name();
    println!(
        "rollout: task={} scheduler={} sd={} reqs={} instances={}",
        cfg.name, name, sd.name(), cfg.reqs_per_iter, cfg.n_instances
    );
    let out = run_rollout(&cfg, &sys, sched, sd, scale.seed);
    let m = &out.metrics;
    println!(
        "makespan {:.1}s  throughput {:.0} tok/s  tail(10%) {:.1}s  \
         preemptions {}  migrations {}  util {:.2}  τ {:.2}",
        m.makespan.as_secs_f64(),
        m.throughput(),
        m.tail_time(0.10).as_secs_f64(),
        m.preemptions,
        m.migrations,
        m.mean_utilization(),
        m.mean_acceptance_len(),
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    use seer::rl::{GrpoConfig, GrpoTrainer};
    use seer::runtime::manifest::default_artifact_dir;
    use seer::runtime::ModelRuntime;
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    let preset = args.get_or("preset", "small");
    let iters = args.get_usize("iters", 30);
    println!("loading artifacts '{preset}' from {dir:?}");
    let model = ModelRuntime::load(&dir, preset)?;
    println!("platform: {}  params: {} leaves", model.platform(), model.n_param_leaves());
    let cfg = GrpoConfig {
        use_spec: args.has_flag("spec"),
        seed: args.get_u64("seed", 0),
        ..Default::default()
    };
    let mut trainer = GrpoTrainer::new(model, cfg);
    for i in 0..iters {
        let s = trainer.run_iteration(i)?;
        println!(
            "iter {:>3}  reward {:.3}  loss {:+.4}  tokens {}  rollout {:.2}s  train {:.2}s",
            s.iter, s.mean_reward, s.mean_loss, s.tokens, s.rollout_secs, s.train_secs
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("seer {} — DESIGN.md documents the architecture;", env!("CARGO_PKG_VERSION"));
    println!("EXPERIMENTS.md records paper-vs-measured for every table/figure.");
    match seer::runtime::Runtime::cpu() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
    let dir = seer::runtime::manifest::default_artifact_dir();
    for preset in ["tiny", "small", "medium"] {
        match seer::runtime::Manifest::load(&dir, preset) {
            Ok(m) => println!(
                "artifacts[{preset}]: {} entries, {} params, pallas={}",
                m.entries.len(),
                m.n_params,
                m.use_pallas
            ),
            Err(_) => println!("artifacts[{preset}]: not built"),
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env(&["full", "fast", "spec"]);
    match args.positionals.first().map(|s| s.as_str()) {
        Some("experiment") => {
            let id = args
                .positionals
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            seer::experiments::run(id, &args)
        }
        Some("rollout") => cmd_rollout(&args),
        Some("train") => cmd_train(&args),
        Some("info") => cmd_info(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
