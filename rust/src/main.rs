//! `seer` — CLI entrypoint for the rollout coordinator, experiment
//! harness, and end-to-end GRPO training.
//!
//! Subcommands:
//!   seer experiment <id|all> [--full] [--seed N] [--iters N]
//!   seer rollout --task <moonlight|qwen|kimi> --scheduler <name> [--sd <strategy>] [--faults FILE] [--json]
//!   seer sweep [--task moonlight] [--schedulers a,b] [--seeds N] [--threads N] [--out F] [--bench-out F]
//!   seer train [--task moonlight] [--iters N] [--save-ctx F] [--load-ctx F]
//!   seer train --real [--preset small] [--iters N] [--artifacts DIR]
//!   seer serve [--addr HOST:PORT] [--workers N] [--state-dir DIR]
//!   seer info
//!
//! All rollout construction goes through `rollout::RolloutSession` and
//! the policy registry — no scheduler/SD match arms live here.

use anyhow::Result;
use seer::config::TaskPreset;
use seer::rollout::RolloutSession;
use seer::util::cli::Args;

const USAGE: &str = "\
seer — reproduction of 'Seer: Online Context Learning for Fast Synchronous \
LLM Reinforcement Learning'

Rollouts are constructed through the unified session layer
(rollout::session): one RolloutSession builder in front of both the
discrete-event cluster simulator and the real-model engine, with
schedulers and SD strategies resolved by name from the policy registry.

USAGE:
  seer experiment <table1|table2|table3|table4|fig2|fig3|fig4|fig7|fig8|fig9|fig10|fig11|fig12|multi-iter|faults|sd-realism|async-frontier|trainer-elastic|all>
       [--full] [--seed N] [--iters N]
  seer rollout --task <moonlight|qwen|kimi> [--scheduler <seer|verl|streamrl|rollpacker|no-context|oracle>]
       [--sd <none|grouped-cst|suffix-decoding|draft-model|mtp>] [--full] [--seed N]
       [--faults FILE] [--bubble F] [--json] [--profile]
  seer sweep [--task <moonlight|qwen|kimi>] [--schedulers a,b,c] [--sd S]
       [--mode m1,m2] [--lag N] [--seeds N] [--seed BASE] [--scales a,b]
       [--drifts x,y] [--faults FILE] [--bubble F] [--threads N] [--out FILE]
       [--bench-out FILE] [--full]
  seer train [--task moonlight|qwen|kimi] [--iters N] [--seed N] [--drift F]
       [--mode sync|hybrid|async] [--lag N] [--json] [--cold]
       [--save-ctx FILE] [--load-ctx FILE] [--scheduler S] [--sd S]
       [--trainer-faults FILE] [--full]
  seer train --real [--preset tiny|small] [--iters N] [--artifacts DIR] [--spec]
  seer serve [--addr HOST:PORT] [--workers N] [--state-dir DIR]
       [--max-per-tenant N] [--max-jobs N] [--keep-ckpts N]
       [--retry-seed N] [--retry-base-ms N] [--retry-cap-ms N]
  seer info

  rollout --json prints the unified RolloutReport as one JSON object for
  bench/trajectory tooling instead of the human summary line.

  rollout --profile prints a wall-time breakdown of the event loop to
  stderr when the run completes (scheduler passes vs engine commit/plan
  vs observer emission, pass counts, mean waiting-set size) — perf
  attribution without an external profiler. Wall clock never enters the
  report, so --profile cannot change any emitted number.

  rollout/sweep --bubble F sets the bubble-drafting fraction
  (SystemConfig::bubble_draft_frac, BubbleSpec-style): end-of-rollout
  idle instances back deeper draft windows for the stragglers. 0 (the
  default) disables it; `seer experiment sd-realism` measures the gain.

  rollout --faults FILE replays a deterministic fault & elasticity script
  (JSON: instance crashes, stragglers, recoveries, scale events, request
  aborts) against the chosen scheduler — same seed + same script give a
  bit-identical report, so scripts are directly comparable across
  schedulers (see `seer experiment faults`).

  sweep expands a study grid (schedulers x seeds x scales x fault plans x
  drifts) and executes it across worker threads with deterministic,
  order-independent aggregation: the JSON report on stdout is
  byte-identical for any --threads value (wall-clock goes to stderr).
  The report carries per-cell results, per-group means with
  seeded-bootstrap CIs, and per-seed paired speedup / tail-reduction of
  every scheduler against the first one listed. Unlike rollout --faults,
  sweep --faults adds a *dimension*: every grid point runs both healthy
  ("none") and under the script, so rows compare like-for-like — the
  cell count doubles (printed up front on stderr). --bench-out
  additionally writes the sim hot-path BENCH_rollout.json baselines
  (SEER_BENCH_MS=0 for the single-iteration CI smoke mode).

  train runs the simulation to N total GRPO iterations through the
  multi-iteration driver, warm-starting each from the cross-iteration
  context store (disable with --cold). --save-ctx / --load-ctx persist
  the store between runs; --iters is a *total* count, so a run resumed
  with --load-ctx continues the epoch sequence up to N overall (a store
  that already observed N iterations runs nothing) — identical to the
  serve plane's train-job accounting. --real instead drives the
  real-model GRPO loop over the AOT HLO artifacts.

  train --mode selects the rollout/training overlap discipline: sync
  (strictly serial, the default), hybrid (one-step overlap, Laminar
  style), or async with --lag N (epoch k's rollout may start once
  update k-1-N has landed; updates land mid-rollout and bump the
  stamped policy version). --mode async --lag 0 reproduces sync
  byte-identically. --json prints one IterationSummary JSON object per
  line (NDJSON) instead of the human table; the summaries carry the
  pipeline clock (rollout_start_secs, update_land_secs) and the
  per-epoch staleness aggregates. sweep --mode m1,m2 adds the same
  knob as a grid dimension (every cell runs under each mode; --lag
  applies to async entries).

  train --trainer-faults FILE replays a deterministic *trainer-side*
  fault script (JSON events trainer_slowdown / trainer_stall /
  trainer_crash) into the overlap recurrence: slowdown windows and
  stalls inflate the train+update interval, a crash redoes the step
  from its last checkpoint. Summaries gain train_retries and
  trainer_fault_secs columns; cluster-side events in the same file are
  ignored here (they belong to rollout/sweep --faults). sweep --faults
  FILE routes the trainer-side half of the script into every
  pipelined cell the same way. --mode async --lag 0 under a trainer
  plan stays byte-identical to --mode sync — pinned by `seer
  experiment trainer-elastic` and the chaos tests.

  serve runs the persistent control plane: a daemon accepting rollout /
  sweep / train jobs as line-delimited JSON over TCP (verbs submit,
  status, result, cancel, subscribe, shutdown) with per-tenant admission
  quotas, live NDJSON event streaming, and — with --state-dir — train
  checkpoints written after every iteration, which a restarted daemon
  recovers and resumes to a byte-identical final report. All human
  output goes to stderr (threshold via SEER_LOG=error|warn|info|debug);
  stdout carries only protocol replies. The protocol grammar and a
  sample shell client are in ARCHITECTURE.md (serve-plane section).

  serve supervision (PR 10): submit envelopes accept deadline_secs
  (wall-clock budget; terminal status deadline-exceeded), priority
  (overload shedding evicts the newest queued job of strictly lower
  priority when --max-jobs is hit), and max_attempts (bounded retry of
  I/O-caused failures with deterministic capped-exponential backoff —
  tune with --retry-seed/--retry-base-ms/--retry-cap-ms; attempts are
  surfaced in status/result). Checkpoints are checksummed and rotated
  (--keep-ckpts N generations, default 3); recovery falls back to the
  newest *valid* generation when the latest is truncated or corrupt.
";

/// Parse the shared `--lag` flag (async off-policy bound).
fn parse_lag(args: &Args) -> Result<Option<u64>> {
    match args.get("lag") {
        None => Ok(None),
        Some(v) => v
            .parse::<u64>()
            .map(Some)
            .map_err(|_| anyhow::anyhow!("bad --lag: {v}")),
    }
}

fn cmd_rollout(args: &Args) -> Result<()> {
    let preset = TaskPreset::from_name(args.get_or("task", "moonlight"))
        .ok_or_else(|| anyhow::anyhow!("unknown --task"))?;
    let scale = seer::experiments::common::Scale::from_args(
        !args.has_flag("full"),
        args,
    );
    let cfg = scale.workload(preset);
    let mut sys = scale.sys(&cfg);
    sys.bubble_draft_frac = args.get_f64("bubble", 0.0);
    let json = args.has_flag("json");
    let mut builder = RolloutSession::builder()
        .workload(cfg.clone())
        .system(sys)
        .scheduler(args.get_or("scheduler", "seer"))
        .sd(args.get_or("sd", "grouped-cst"))
        .seed(scale.seed)
        .profile(args.has_flag("profile"));
    let mut n_faults = 0usize;
    if let Some(path) = args.get("faults") {
        let plan =
            seer::sim::faults::FaultPlan::load(std::path::Path::new(path))?;
        n_faults = plan.len();
        builder = builder.faults(plan);
    }
    let session = builder.build()?;
    if !json {
        println!(
            "rollout: task={} scheduler={} sd={} reqs={} instances={} faults={}",
            cfg.name,
            session.scheduler_name(),
            session.sd_name(),
            cfg.reqs_per_iter,
            cfg.n_instances,
            n_faults,
        );
    }
    let report = session.run()?;
    if json {
        println!("{}", report.to_json());
        return Ok(());
    }
    let m = &report.metrics;
    println!(
        "makespan {:.1}s  throughput {:.0} tok/s  tail(10%) {:.1}s  \
         preemptions {}  migrations {}  util {:.2}  τ {:.2}",
        m.makespan.as_secs_f64(),
        m.throughput(),
        m.tail_time(0.10).as_secs_f64(),
        m.preemptions,
        m.migrations,
        m.mean_utilization(),
        m.mean_acceptance_len(),
    );
    if m.instances_lost + m.instances_added + m.aborted > 0 {
        println!(
            "faults: instances lost {}  added {}  requeued {}  \
             lost tokens {}  aborted {}  mean recovery {:.1}s",
            m.instances_lost,
            m.instances_added,
            m.fault_requeued,
            m.fault_lost_tokens,
            m.aborted,
            m.mean_recovery_latency().as_secs_f64(),
        );
    }
    Ok(())
}

/// Parallel deterministic sweep: expand a study grid and execute it
/// across worker threads, printing the byte-stable JSON report.
fn cmd_sweep(args: &Args) -> Result<()> {
    use seer::serve::log;
    use seer::sweep::{SweepRunner, SweepSpec};
    let preset = TaskPreset::from_name(args.get_or("task", "moonlight"))
        .ok_or_else(|| anyhow::anyhow!("unknown --task"))?;
    let scale = seer::experiments::common::Scale::from_args(
        !args.has_flag("full"),
        args,
    );
    let workload = scale.workload(preset);
    let mut system = scale.sys(&workload);
    system.bubble_draft_frac = args.get_f64("bubble", 0.0);
    let schedulers: Vec<String> = args
        .get_or("schedulers", "seer,verl,streamrl,rollpacker")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    let n_seeds = args.get_usize("seeds", 3).max(1);
    let mut spec = SweepSpec::new(workload)
        .system(system)
        .sd(args.get_or("sd", "grouped-cst"))
        .seeds((0..n_seeds as u64).map(|i| scale.seed + i));
    spec.schedulers = schedulers;
    if let Some(s) = args.get("scales") {
        spec.scales = s
            .split(',')
            .map(|x| x.parse().map_err(|_| anyhow::anyhow!("bad --scales: {x}")))
            .collect::<Result<_>>()?;
    }
    if let Some(s) = args.get("drifts") {
        spec.drifts = s
            .split(',')
            .map(|x| x.parse().map_err(|_| anyhow::anyhow!("bad --drifts: {x}")))
            .collect::<Result<_>>()?;
    }
    // Dimension validity (scale >= 1, drifts finite and >= 0) is checked
    // once, by SweepSpec::validate inside SweepRunner::run.
    if let Some(s) = args.get("mode") {
        // Training-mode dimension: every cell runs under each listed
        // overlap discipline; --lag applies to the async entries.
        let lag = parse_lag(args)?;
        for item in s.split(',').filter(|m| !m.is_empty()) {
            let mode = seer::config::TrainingMode::parse(
                item,
                if item == "async" { lag } else { None },
            )?;
            spec = spec.mode(mode);
        }
    }
    if let Some(path) = args.get("faults") {
        let plan =
            seer::sim::faults::FaultPlan::load(std::path::Path::new(path))?;
        // Faults become a dimension: every cell runs healthy AND faulted.
        spec = spec
            .fault_plan("none", seer::sim::faults::FaultPlan::new())
            .fault_plan(path, plan);
    }
    let runner = match args.get_usize("threads", 0) {
        0 => SweepRunner::from_env(),
        n => SweepRunner::new(n),
    };
    log::info(
        "sweep",
        format!(
            "task={} cells={} threads={} (schedulers {:?}, {} seeds)",
            spec.workload.name,
            spec.cardinality(),
            runner.threads(),
            spec.schedulers,
            n_seeds,
        ),
    );
    let outcome = runner.run(&spec)?;
    log::info(
        "sweep",
        format!(
            "wall {:.2}s for {} cells on {} threads",
            outcome.wall_secs,
            outcome.report.cells.len(),
            runner.threads(),
        ),
    );
    let json = outcome.report.to_json().to_string();
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &json)?;
            log::info("sweep", format!("report written to {path}"));
        }
        None => println!("{json}"),
    }
    if let Some(path) = args.get("bench-out") {
        let suite = seer::sweep::rollout_bench_suite(&spec.schedulers)?;
        suite.write(std::path::Path::new(path))?;
        log::info("sweep", format!("bench baselines written to {path}"));
    }
    Ok(())
}

/// Simulated multi-iteration training: N GRPO epochs through the
/// `TrainingDriver`, warm-started from the cross-iteration context store.
fn cmd_train_sim(args: &Args) -> Result<()> {
    use seer::iteration::{ContextStore, TrainingConfig, TrainingDriver};
    let preset = TaskPreset::from_name(args.get_or("task", "moonlight"))
        .ok_or_else(|| anyhow::anyhow!("unknown --task"))?;
    let scale = seer::experiments::common::Scale::from_args(
        !args.has_flag("full"),
        args,
    );
    let workload = scale.workload(preset);
    let system = scale.sys(&workload);
    let mode = seer::config::TrainingMode::parse(
        args.get_or("mode", "sync"),
        parse_lag(args)?,
    )?;
    // Trainer-side fault script: only the trainer half of the plan is
    // replayed here; cluster-side events belong to rollout --faults.
    let trainer_faults = match args.get("trainer-faults") {
        Some(path) => {
            let plan =
                seer::sim::faults::FaultPlan::load(std::path::Path::new(path))?;
            let (_, trainer) = plan.partition();
            trainer
        }
        None => seer::sim::faults::FaultPlan::new(),
    };
    let cfg = TrainingConfig {
        system,
        scheduler: args.get_or("scheduler", "seer").to_string(),
        sd: args.get_or("sd", "grouped-cst").to_string(),
        iters: args.get_usize("iters", 3),
        seed: scale.seed,
        drift: args.get_f64("drift", 0.05),
        warm_start: !args.has_flag("cold"),
        mode,
        trainer_faults,
        ..TrainingConfig::new(workload)
    };
    let json = args.has_flag("json");
    let mut driver = match args.get("load-ctx") {
        Some(path) => {
            let store = ContextStore::load(std::path::Path::new(path))?;
            if !json {
                println!(
                    "loaded context store from {path}: {} groups, {} iterations",
                    store.len(),
                    store.iterations()
                );
            }
            // with_store refuses fingerprint mismatches (task/seed/scale).
            TrainingDriver::with_store(cfg.clone(), store)?
        }
        None => TrainingDriver::new(cfg.clone()),
    };
    if !json {
        println!(
            "train: task={} scheduler={} sd={} iters={} drift={} warm={} mode={} lag={}",
            cfg.workload.name,
            cfg.scheduler,
            cfg.sd,
            cfg.iters,
            cfg.drift,
            cfg.warm_start,
            cfg.mode.mode_str(),
            cfg.mode.lag(),
        );
    }
    // Total-count semantics, shared with the serve plane: run *to*
    // cfg.iters epochs overall, counting epochs a --load-ctx store
    // already observed.
    while driver.next_epoch() < cfg.iters {
        let s = driver.run_iteration(driver.next_epoch())?;
        if json {
            // NDJSON: one IterationSummary object per line.
            println!("{}", s.to_json());
        } else {
            println!(
                "iter {:>3} {}  rollout {:>8.1}s  p99 {:>8.1}s  tail {:>7.1}s  \
                 train {:>6.1}s  update {:>5.1}s  total {:>8.1}s  {:>7.0} tok/s  \
                 stale {:>4}",
                s.iter,
                if s.warm { "warm" } else { "cold" },
                s.makespan_secs,
                s.p99_finish_secs,
                s.tail_secs,
                s.train_secs,
                s.weight_update_secs,
                s.iter_total_secs,
                s.throughput_tok_s,
                s.stale_requests,
            );
        }
    }
    if let Some(path) = args.get("save-ctx") {
        driver.store().save(std::path::Path::new(path))?;
        if !json {
            println!(
                "saved context store to {path}: {} groups, {} iterations",
                driver.store().len(),
                driver.store().iterations()
            );
        }
    }
    Ok(())
}

/// Real-model GRPO over the AOT HLO artifacts (`seer train --real`).
fn cmd_train_real(args: &Args) -> Result<()> {
    use seer::rl::{GrpoConfig, GrpoTrainer};
    use seer::runtime::manifest::default_artifact_dir;
    use seer::runtime::ModelRuntime;
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    let preset = args.get_or("preset", "small");
    let iters = args.get_usize("iters", 30);
    println!("loading artifacts '{preset}' from {dir:?}");
    let model = ModelRuntime::load(&dir, preset)?;
    println!("platform: {}  params: {} leaves", model.platform(), model.n_param_leaves());
    let cfg = GrpoConfig {
        use_spec: args.has_flag("spec"),
        seed: args.get_u64("seed", 0),
        ..Default::default()
    };
    let mut trainer = GrpoTrainer::new(model, cfg);
    for i in 0..iters {
        let s = trainer.run_iteration(i)?;
        println!(
            "iter {:>3}  reward {:.3}  loss {:+.4}  tokens {}  rollout {:.2}s  train {:.2}s",
            s.iter, s.mean_reward, s.mean_loss, s.tokens, s.rollout_secs, s.train_secs
        );
    }
    Ok(())
}

/// Persistent control plane: a daemon running rollout/sweep/train jobs
/// submitted as line-delimited JSON over TCP. Blocks until a client
/// sends `shutdown` and the admitted jobs finish.
fn cmd_serve(args: &Args) -> Result<()> {
    use seer::serve::{
        QuotaConfig, RetryPolicy, ServeConfig, Server, TrainCheckpoint,
    };
    let defaults = QuotaConfig::default();
    let retry_defaults = RetryPolicy::default();
    let cfg = ServeConfig {
        addr: args.get_or("addr", "127.0.0.1:7878").to_string(),
        workers: args.get_usize("workers", 0),
        quota: QuotaConfig {
            max_per_tenant: args
                .get_usize("max-per-tenant", defaults.max_per_tenant),
            max_jobs: args.get_usize("max-jobs", defaults.max_jobs),
        },
        state_dir: args.get("state-dir").map(std::path::PathBuf::from),
        keep_ckpts: args
            .get_usize("keep-ckpts", TrainCheckpoint::DEFAULT_KEEP),
        retry: RetryPolicy {
            base_ms: args.get_u64("retry-base-ms", retry_defaults.base_ms),
            cap_ms: args.get_u64("retry-cap-ms", retry_defaults.cap_ms),
            seed: args.get_u64("retry-seed", retry_defaults.seed),
        },
    };
    Server::bind(cfg)?.run()
}

fn cmd_info() -> Result<()> {
    println!("seer {} — ARCHITECTURE.md documents the architecture;", env!("CARGO_PKG_VERSION"));
    println!("README.md maps every paper table/figure to its experiment id.");
    match seer::runtime::Runtime::cpu() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
    let dir = seer::runtime::manifest::default_artifact_dir();
    for preset in ["tiny", "small", "medium"] {
        match seer::runtime::Manifest::load(&dir, preset) {
            Ok(m) => println!(
                "artifacts[{preset}]: {} entries, {} params, pallas={}",
                m.entries.len(),
                m.n_params,
                m.use_pallas
            ),
            Err(_) => println!("artifacts[{preset}]: not built"),
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env(&[
        "full", "fast", "spec", "json", "real", "cold", "profile",
    ]);
    match args.positionals.first().map(|s| s.as_str()) {
        Some("experiment") => {
            let id = args
                .positionals
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            seer::experiments::run(id, &args)
        }
        Some("rollout") => cmd_rollout(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("train") if args.has_flag("real") => cmd_train_real(&args),
        Some("train") => cmd_train_sim(&args),
        Some("serve") => cmd_serve(&args),
        Some("info") => cmd_info(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
