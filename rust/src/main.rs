//! `seer` — CLI entrypoint for the rollout coordinator, experiment
//! harness, and end-to-end GRPO training.
//!
//! Subcommands:
//!   seer experiment <id|all> [--full] [--seed N] [--iters N]
//!   seer rollout --task <moonlight|qwen|kimi> --scheduler <name> [--sd <strategy>] [--json]
//!   seer train [--preset small] [--iters N] [--artifacts DIR]
//!   seer info
//!
//! All rollout construction goes through `rollout::RolloutSession` and
//! the policy registry — no scheduler/SD match arms live here.

use anyhow::Result;
use seer::config::TaskPreset;
use seer::rollout::RolloutSession;
use seer::util::cli::Args;

const USAGE: &str = "\
seer — reproduction of 'Seer: Online Context Learning for Fast Synchronous \
LLM Reinforcement Learning'

Rollouts are constructed through the unified session layer
(rollout::session): one RolloutSession builder in front of both the
discrete-event cluster simulator and the real-model engine, with
schedulers and SD strategies resolved by name from the policy registry.

USAGE:
  seer experiment <table1|table2|table3|table4|fig2|fig3|fig4|fig7|fig8|fig9|fig10|fig11|fig12|all>
       [--full] [--seed N] [--iters N]
  seer rollout --task <moonlight|qwen|kimi> [--scheduler <seer|verl|streamrl|no-context|oracle>]
       [--sd <none|grouped-cst|suffix-decoding|draft-model|mtp>] [--full] [--seed N] [--json]
  seer train [--preset tiny|small] [--iters N] [--artifacts DIR] [--spec]
  seer info

  rollout --json prints the unified RolloutReport as one JSON object for
  bench/trajectory tooling instead of the human summary line.
";

fn cmd_rollout(args: &Args) -> Result<()> {
    let preset = TaskPreset::from_name(args.get_or("task", "moonlight"))
        .ok_or_else(|| anyhow::anyhow!("unknown --task"))?;
    let scale = seer::experiments::common::Scale::from_args(
        !args.has_flag("full"),
        args,
    );
    let cfg = scale.workload(preset);
    let sys = scale.sys(&cfg);
    let json = args.has_flag("json");
    let session = RolloutSession::builder()
        .workload(cfg.clone())
        .system(sys)
        .scheduler(args.get_or("scheduler", "seer"))
        .sd(args.get_or("sd", "grouped-cst"))
        .seed(scale.seed)
        .build()?;
    if !json {
        println!(
            "rollout: task={} scheduler={} sd={} reqs={} instances={}",
            cfg.name,
            session.scheduler_name(),
            session.sd_name(),
            cfg.reqs_per_iter,
            cfg.n_instances
        );
    }
    let report = session.run()?;
    if json {
        println!("{}", report.to_json());
        return Ok(());
    }
    let m = &report.metrics;
    println!(
        "makespan {:.1}s  throughput {:.0} tok/s  tail(10%) {:.1}s  \
         preemptions {}  migrations {}  util {:.2}  τ {:.2}",
        m.makespan.as_secs_f64(),
        m.throughput(),
        m.tail_time(0.10).as_secs_f64(),
        m.preemptions,
        m.migrations,
        m.mean_utilization(),
        m.mean_acceptance_len(),
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    use seer::rl::{GrpoConfig, GrpoTrainer};
    use seer::runtime::manifest::default_artifact_dir;
    use seer::runtime::ModelRuntime;
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    let preset = args.get_or("preset", "small");
    let iters = args.get_usize("iters", 30);
    println!("loading artifacts '{preset}' from {dir:?}");
    let model = ModelRuntime::load(&dir, preset)?;
    println!("platform: {}  params: {} leaves", model.platform(), model.n_param_leaves());
    let cfg = GrpoConfig {
        use_spec: args.has_flag("spec"),
        seed: args.get_u64("seed", 0),
        ..Default::default()
    };
    let mut trainer = GrpoTrainer::new(model, cfg);
    for i in 0..iters {
        let s = trainer.run_iteration(i)?;
        println!(
            "iter {:>3}  reward {:.3}  loss {:+.4}  tokens {}  rollout {:.2}s  train {:.2}s",
            s.iter, s.mean_reward, s.mean_loss, s.tokens, s.rollout_secs, s.train_secs
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("seer {} — DESIGN.md documents the architecture;", env!("CARGO_PKG_VERSION"));
    println!("EXPERIMENTS.md records paper-vs-measured for every table/figure.");
    match seer::runtime::Runtime::cpu() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
    let dir = seer::runtime::manifest::default_artifact_dir();
    for preset in ["tiny", "small", "medium"] {
        match seer::runtime::Manifest::load(&dir, preset) {
            Ok(m) => println!(
                "artifacts[{preset}]: {} entries, {} params, pallas={}",
                m.entries.len(),
                m.n_params,
                m.use_pallas
            ),
            Err(_) => println!("artifacts[{preset}]: not built"),
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env(&["full", "fast", "spec", "json"]);
    match args.positionals.first().map(|s| s.as_str()) {
        Some("experiment") => {
            let id = args
                .positionals
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            seer::experiments::run(id, &args)
        }
        Some("rollout") => cmd_rollout(&args),
        Some("train") => cmd_train(&args),
        Some("info") => cmd_info(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
