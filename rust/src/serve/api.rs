//! The serve plane's wire protocol: line-delimited JSON over TCP.
//!
//! Every client request is one JSON object on one line, tagged by a
//! `verb`; every reply is one JSON object with an `ok` boolean. Error
//! replies carry a machine code (`bad-request`, `quota`, `not-found`,
//! `shutting-down`) plus a human `error` string. The grammar is spelled
//! out in ARCHITECTURE.md (serve-plane section); this module is its
//! single implementation — the server parses with [`Request::parse`],
//! and the integration tests build their reference runs from the *same*
//! [`RolloutParams::session`] / [`TrainParams::training_config`]
//! helpers the executor uses, which is what makes "the stream equals a
//! direct run" testable at all.
//!
//! Parsing is strict about types: an absent optional field takes its
//! default, but a present field of the wrong JSON type is an error —
//! silently defaulting a mistyped `"seed": "42"` would run the wrong
//! job and report nothing.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::config::{TaskPreset, TrainingMode, WorkloadConfig};
use crate::iteration::{IterationSummary, TrainingConfig};
use crate::rollout::{PolicyRegistry, RolloutSession, RolloutSessionBuilder};
use crate::sim::faults::FaultPlan;
use crate::util::json::Json;

/// Upper bound on request-line length the server will read (1 MiB).
/// Longer lines are answered with `bad-request` and the connection is
/// closed — an unbounded line is memory exhaustion, not a request.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// Parameters of a single-rollout job.
#[derive(Debug, Clone, PartialEq)]
pub struct RolloutParams {
    /// Task preset name ([`TaskPreset::from_name`]).
    pub task: String,
    pub scheduler: String,
    pub sd: String,
    pub seed: u64,
    /// Bubble-drafting fraction (`SystemConfig::bubble_draft_frac`);
    /// 0 disables, validated into `[0, 1]` at parse time.
    pub bubble: f64,
    /// Paper-scale workload instead of the test-scale variant.
    pub full: bool,
}

/// Parameters of a sweep-grid job.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepParams {
    pub task: String,
    pub schedulers: Vec<String>,
    pub sd: String,
    pub seeds: Vec<u64>,
    pub full: bool,
}

/// Parameters of a multi-iteration train job.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainParams {
    pub task: String,
    pub scheduler: String,
    pub sd: String,
    pub iters: usize,
    pub seed: u64,
    pub drift: f64,
    /// Rollout/training overlap mode (`sync`, `hybrid`, or `async` with
    /// a `lag` field); see [`TrainingMode::parse`].
    pub mode: TrainingMode,
    /// Disable warm starts from the context store.
    pub cold: bool,
    /// Sleep this long after each iteration. Emulates the pacing of an
    /// external training engine (weight sync, optimizer step) that the
    /// simulator models but does not wait for — and gives the recovery
    /// tests a deterministic window to interrupt a job mid-run.
    pub throttle_ms: u64,
    /// Scripted trainer-side fault plan (slowdown windows, stalls,
    /// crashes) replayed into the overlap recurrence; empty = healthy
    /// trainer. Only trainer-side events are accepted here — cluster
    /// faults belong to the rollout engine, not the train loop.
    pub trainer_faults: FaultPlan,
    pub full: bool,
}

/// Per-job supervision knobs, parsed from the submit envelope alongside
/// the spec. Deliberately *not* part of [`JobSpec`]: checkpoints
/// persist the spec only, so a job recovered after a daemon restart
/// runs under default control (no deadline or retry budget survives the
/// restart — the recovered run is the retry).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobControl {
    /// Wall-clock budget once the job starts running; exceeding it ends
    /// the job with terminal status `deadline-exceeded`. `None` means
    /// unbounded. Wall-clock is used only for this supervision decision
    /// — it never reaches a report.
    pub deadline_secs: Option<f64>,
    /// Shedding rank. Under global-cap pressure the daemon sheds the
    /// newest *queued* job of strictly lower priority to admit this
    /// one; equal-priority jobs are never shed. Default 0.
    pub priority: u64,
    /// Total execution attempts (1 = no retry). Retryable failures are
    /// re-queued with deterministic capped-exponential backoff until
    /// the budget is spent; fatal errors fail on the first attempt.
    pub max_attempts: u64,
}

impl Default for JobControl {
    fn default() -> Self {
        JobControl {
            deadline_secs: None,
            priority: 0,
            max_attempts: 1,
        }
    }
}

impl JobControl {
    /// Upper bound on `max_attempts` — a retry budget is a supervision
    /// tool, not a crash-loop license.
    pub const MAX_ATTEMPTS: u64 = 8;

    /// Parse the control fields out of a submit's `job` object. Absent
    /// fields take defaults; present-but-invalid fields are errors.
    pub fn from_json(j: &Json) -> Result<JobControl> {
        let deadline_secs = match j.get("deadline_secs") {
            None => None,
            Some(v) => {
                let d = v
                    .as_f64()
                    .context("field 'deadline_secs' must be a number")?;
                if !(d.is_finite() && d > 0.0) {
                    bail!("deadline_secs must be finite and > 0");
                }
                Some(d)
            }
        };
        let priority = opt_u64(j, "priority", 0)?;
        let max_attempts = opt_u64(j, "max_attempts", 1)?;
        if !(1..=Self::MAX_ATTEMPTS).contains(&max_attempts) {
            bail!(
                "max_attempts must be in 1..={} (got {max_attempts})",
                Self::MAX_ATTEMPTS
            );
        }
        Ok(JobControl {
            deadline_secs,
            priority,
            max_attempts,
        })
    }
}

/// What a `submit` asks the daemon to run.
#[derive(Debug, Clone, PartialEq)]
pub enum JobSpec {
    Rollout(RolloutParams),
    Sweep(SweepParams),
    Train(TrainParams),
}

/// One parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Submit {
        tenant: String,
        spec: JobSpec,
        control: JobControl,
    },
    /// One job's status, or — with no id — a whole-daemon summary.
    Status { job: Option<u64> },
    /// Block until the job is terminal, then return its result.
    Result { job: u64 },
    Cancel { job: u64 },
    /// Switch the connection to an NDJSON event stream for the job.
    Subscribe { job: u64 },
    /// Stop the daemon: `abort` cancels running jobs at their next
    /// cancellation point (checkpoints retained), otherwise every
    /// admitted job drains first.
    Shutdown { abort: bool },
}

// -- typed field access ------------------------------------------------
// Absent → default; present-but-mistyped → named error.

fn opt_str(j: &Json, k: &str, default: &str) -> Result<String> {
    match j.get(k) {
        None => Ok(default.to_string()),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .with_context(|| format!("field '{k}' must be a string")),
    }
}

fn opt_u64(j: &Json, k: &str, default: u64) -> Result<u64> {
    match j.get(k) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .with_context(|| format!("field '{k}' must be a number")),
    }
}

fn opt_f64(j: &Json, k: &str, default: f64) -> Result<f64> {
    match j.get(k) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .with_context(|| format!("field '{k}' must be a number")),
    }
}

fn opt_bool(j: &Json, k: &str, default: bool) -> Result<bool> {
    match j.get(k) {
        None => Ok(default),
        Some(v) => v
            .as_bool()
            .with_context(|| format!("field '{k}' must be a boolean")),
    }
}

fn req_u64(j: &Json, k: &str) -> Result<u64> {
    j.get(k)
        .with_context(|| format!("missing field '{k}'"))?
        .as_u64()
        .with_context(|| format!("field '{k}' must be a number"))
}

/// Resolve and validate a task name.
fn preset(task: &str) -> Result<TaskPreset> {
    TaskPreset::from_name(task)
        .with_context(|| format!("unknown task '{task}'"))
}

fn workload_of(task: &str, full: bool) -> Result<WorkloadConfig> {
    let p = preset(task)?;
    Ok(if full { p.workload() } else { p.workload_for_test() })
}

/// Validate scheduler / SD names against the builtin registry at parse
/// time, so a typo is rejected at `submit` — not hours later when the
/// job reaches a worker.
fn check_policies(scheduler: &str, sd: &str) -> Result<()> {
    let reg = PolicyRegistry::builtin();
    reg.scheduler(scheduler)?;
    reg.sd(sd)?;
    Ok(())
}

impl JobSpec {
    pub fn from_json(j: &Json) -> Result<JobSpec> {
        if j.as_obj().is_none() {
            bail!("job must be an object");
        }
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .context("job needs a string 'kind' (rollout|sweep|train)")?;
        let full = opt_bool(j, "full", false)?;
        match kind {
            "rollout" => {
                let p = RolloutParams {
                    task: opt_str(j, "task", "moonlight")?,
                    scheduler: opt_str(j, "scheduler", "seer")?,
                    sd: opt_str(j, "sd", "grouped-cst")?,
                    seed: opt_u64(j, "seed", 42)?,
                    bubble: opt_f64(j, "bubble", 0.0)?,
                    full,
                };
                if !(p.bubble.is_finite() && (0.0..=1.0).contains(&p.bubble)) {
                    bail!("rollout bubble must be in [0, 1]");
                }
                preset(&p.task)?;
                check_policies(&p.scheduler, &p.sd)?;
                Ok(JobSpec::Rollout(p))
            }
            "sweep" => {
                let schedulers = match j.get("schedulers") {
                    None => vec!["seer".to_string(), "verl".to_string()],
                    Some(v) => v
                        .as_arr()
                        .context("field 'schedulers' must be an array")?
                        .iter()
                        .map(|s| {
                            s.as_str().map(str::to_string).context(
                                "field 'schedulers' must hold strings",
                            )
                        })
                        .collect::<Result<Vec<_>>>()?,
                };
                let seeds = match j.get("seeds") {
                    None => vec![42, 43],
                    Some(v) => v
                        .as_arr()
                        .context("field 'seeds' must be an array")?
                        .iter()
                        .map(|s| {
                            s.as_u64()
                                .context("field 'seeds' must hold numbers")
                        })
                        .collect::<Result<Vec<_>>>()?,
                };
                if schedulers.is_empty() || seeds.is_empty() {
                    bail!("sweep needs at least one scheduler and one seed");
                }
                let p = SweepParams {
                    task: opt_str(j, "task", "moonlight")?,
                    sd: opt_str(j, "sd", "grouped-cst")?,
                    schedulers,
                    seeds,
                    full,
                };
                preset(&p.task)?;
                for s in &p.schedulers {
                    check_policies(s, &p.sd)?;
                }
                Ok(JobSpec::Sweep(p))
            }
            "train" => {
                let lag = match j.get("lag") {
                    None => None,
                    Some(v) => Some(
                        v.as_u64()
                            .context("field 'lag' must be a number")?,
                    ),
                };
                let trainer_faults = match j.get("trainer_faults") {
                    None => FaultPlan::new(),
                    Some(v) => {
                        let plan = FaultPlan::from_json(v)
                            .context("field 'trainer_faults'")?;
                        plan.validate().context("field 'trainer_faults'")?;
                        if let Some(e) =
                            plan.events.iter().find(|e| !e.event.is_trainer())
                        {
                            bail!(
                                "trainer_faults must hold trainer-side \
                                 events only (got '{}')",
                                e.event.kind()
                            );
                        }
                        plan
                    }
                };
                let p = TrainParams {
                    task: opt_str(j, "task", "moonlight")?,
                    scheduler: opt_str(j, "scheduler", "seer")?,
                    sd: opt_str(j, "sd", "grouped-cst")?,
                    iters: opt_u64(j, "iters", 3)? as usize,
                    seed: opt_u64(j, "seed", 42)?,
                    drift: opt_f64(j, "drift", 0.05)?,
                    mode: TrainingMode::parse(
                        &opt_str(j, "mode", "sync")?,
                        lag,
                    )?,
                    cold: opt_bool(j, "cold", false)?,
                    throttle_ms: opt_u64(j, "throttle_ms", 0)?,
                    trainer_faults,
                    full,
                };
                if p.iters == 0 {
                    bail!("train needs iters >= 1");
                }
                if !(p.drift.is_finite() && p.drift >= 0.0) {
                    bail!("train drift must be finite and >= 0");
                }
                preset(&p.task)?;
                check_policies(&p.scheduler, &p.sd)?;
                Ok(JobSpec::Train(p))
            }
            other => bail!("unknown job kind '{other}'"),
        }
    }

    /// Wire/checkpoint form; [`JobSpec::from_json`] inverts it.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            o.insert(k.to_string(), v);
        };
        match self {
            JobSpec::Rollout(p) => {
                put("kind", Json::Str("rollout".into()));
                put("task", Json::Str(p.task.clone()));
                put("scheduler", Json::Str(p.scheduler.clone()));
                put("sd", Json::Str(p.sd.clone()));
                put("seed", Json::Num(p.seed as f64));
                put("bubble", Json::Num(p.bubble));
                put("full", Json::Bool(p.full));
            }
            JobSpec::Sweep(p) => {
                put("kind", Json::Str("sweep".into()));
                put("task", Json::Str(p.task.clone()));
                put("sd", Json::Str(p.sd.clone()));
                put(
                    "schedulers",
                    Json::Arr(
                        p.schedulers
                            .iter()
                            .map(|s| Json::Str(s.clone()))
                            .collect(),
                    ),
                );
                put(
                    "seeds",
                    Json::Arr(
                        p.seeds.iter().map(|s| Json::Num(*s as f64)).collect(),
                    ),
                );
                put("full", Json::Bool(p.full));
            }
            JobSpec::Train(p) => {
                put("kind", Json::Str("train".into()));
                put("task", Json::Str(p.task.clone()));
                put("scheduler", Json::Str(p.scheduler.clone()));
                put("sd", Json::Str(p.sd.clone()));
                put("iters", Json::Num(p.iters as f64));
                put("seed", Json::Num(p.seed as f64));
                put("drift", Json::Num(p.drift));
                put("mode", Json::Str(p.mode.mode_str().into()));
                if let TrainingMode::Async { lag } = p.mode {
                    put("lag", Json::Num(lag as f64));
                }
                put("cold", Json::Bool(p.cold));
                put("throttle_ms", Json::Num(p.throttle_ms as f64));
                // Omitted when empty so healthy-trainer specs (and the
                // checkpoints embedding them) keep their exact bytes.
                if !p.trainer_faults.is_empty() {
                    put("trainer_faults", p.trainer_faults.to_json());
                }
                put("full", Json::Bool(p.full));
            }
        }
        Json::Obj(o)
    }

    pub fn kind(&self) -> &'static str {
        match self {
            JobSpec::Rollout(_) => "rollout",
            JobSpec::Sweep(_) => "sweep",
            JobSpec::Train(_) => "train",
        }
    }
}

impl RolloutParams {
    /// The session this job runs — public so a test can run the *same*
    /// rollout directly and compare event streams / reports.
    pub fn session(&self) -> Result<RolloutSessionBuilder<'static>> {
        let sys = crate::config::SystemConfig {
            bubble_draft_frac: self.bubble,
            ..Default::default()
        };
        Ok(RolloutSession::builder()
            .workload(workload_of(&self.task, self.full)?)
            .system(sys)
            .scheduler(&self.scheduler)
            .sd(&self.sd)
            .seed(self.seed))
    }
}

impl SweepParams {
    pub fn sweep_spec(&self) -> Result<crate::sweep::SweepSpec> {
        let mut spec =
            crate::sweep::SweepSpec::new(workload_of(&self.task, self.full)?)
                .sd(&self.sd)
                .seeds(self.seeds.iter().copied());
        spec.schedulers = self.schedulers.clone();
        Ok(spec)
    }
}

impl TrainParams {
    /// The training config this job runs — shared with the recovery
    /// tests' uninterrupted reference run.
    pub fn training_config(&self) -> Result<TrainingConfig> {
        Ok(TrainingConfig {
            scheduler: self.scheduler.clone(),
            sd: self.sd.clone(),
            iters: self.iters,
            seed: self.seed,
            drift: self.drift,
            mode: self.mode,
            warm_start: !self.cold,
            trainer_faults: self.trainer_faults.clone(),
            ..TrainingConfig::new(workload_of(&self.task, self.full)?)
        })
    }
}

/// The deterministic final report of a train job: the spec echo plus
/// every per-iteration summary and whole-run totals. Built from
/// [`IterationSummary`] values only, in iteration order, so a resumed
/// run whose history matches an uninterrupted run's produces the same
/// bytes.
pub fn train_report(params: &TrainParams, history: &[IterationSummary]) -> Json {
    let mut o = BTreeMap::new();
    o.insert("spec".to_string(), JobSpec::Train(params.clone()).to_json());
    o.insert(
        "iterations".to_string(),
        Json::Arr(history.iter().map(|s| s.to_json()).collect()),
    );
    let total: f64 = history.iter().map(|s| s.iter_total_secs).sum();
    let tokens: u64 = history.iter().map(|s| s.tokens).sum();
    o.insert("total_secs".to_string(), Json::Num(total));
    o.insert("total_tokens".to_string(), Json::Num(tokens as f64));
    let stale: u64 = history.iter().map(|s| s.stale_requests).sum();
    let stale_max = history.iter().map(|s| s.staleness_max).max().unwrap_or(0);
    o.insert("total_stale_requests".to_string(), Json::Num(stale as f64));
    o.insert("staleness_max".to_string(), Json::Num(stale_max as f64));
    let retries: u64 = history.iter().map(|s| s.train_retries).sum();
    let fault: f64 = history.iter().map(|s| s.trainer_fault_secs).sum();
    o.insert("total_train_retries".to_string(), Json::Num(retries as f64));
    o.insert("total_trainer_fault_secs".to_string(), Json::Num(fault));
    if let Some(last) = history.last() {
        o.insert(
            "final_p99_finish_secs".to_string(),
            Json::Num(last.p99_finish_secs),
        );
    }
    Json::Obj(o)
}

impl Request {
    /// Parse one request line. The error string is ready to embed in a
    /// `bad-request` reply.
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
        if j.as_obj().is_none() {
            bail!("request must be a JSON object");
        }
        let verb = j
            .get("verb")
            .and_then(Json::as_str)
            .context("request needs a string 'verb'")?;
        match verb {
            "submit" => {
                let tenant = opt_str(&j, "tenant", "default")?;
                if tenant.is_empty() {
                    bail!("tenant must be non-empty");
                }
                let job = j.get("job").context("submit needs a 'job' object")?;
                let spec = JobSpec::from_json(job)?;
                let control = JobControl::from_json(job)?;
                Ok(Request::Submit {
                    tenant,
                    spec,
                    control,
                })
            }
            "status" => Ok(Request::Status {
                job: match j.get("job") {
                    None => None,
                    Some(v) => Some(
                        v.as_u64()
                            .context("field 'job' must be a number")?,
                    ),
                },
            }),
            "result" => Ok(Request::Result {
                job: req_u64(&j, "job")?,
            }),
            "cancel" => Ok(Request::Cancel {
                job: req_u64(&j, "job")?,
            }),
            "subscribe" => Ok(Request::Subscribe {
                job: req_u64(&j, "job")?,
            }),
            "shutdown" => {
                let mode = opt_str(&j, "mode", "graceful")?;
                let abort = match mode.as_str() {
                    "graceful" => false,
                    "abort" => true,
                    m => bail!("unknown shutdown mode '{m}'"),
                };
                Ok(Request::Shutdown { abort })
            }
            other => bail!("unknown verb '{other}'"),
        }
    }
}

/// `{"ok":true, ...fields}`.
pub fn ok_reply(fields: Vec<(&str, Json)>) -> Json {
    let mut o = BTreeMap::new();
    o.insert("ok".to_string(), Json::Bool(true));
    for (k, v) in fields {
        o.insert(k.to_string(), v);
    }
    Json::Obj(o)
}

/// `{"ok":false,"code":code,"error":msg}`.
pub fn err_reply(code: &str, msg: &str) -> Json {
    let mut o = BTreeMap::new();
    o.insert("ok".to_string(), Json::Bool(false));
    o.insert("code".to_string(), Json::Str(code.to_string()));
    o.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_submit_with_defaults() {
        let r = Request::parse(
            r#"{"verb":"submit","job":{"kind":"rollout"}}"#,
        )
        .unwrap();
        let Request::Submit {
            tenant,
            spec,
            control,
        } = r
        else {
            panic!("not a submit")
        };
        assert_eq!(tenant, "default");
        assert_eq!(control, JobControl::default());
        let JobSpec::Rollout(p) = spec else { panic!("not rollout") };
        assert_eq!(p.task, "moonlight");
        assert_eq!(p.scheduler, "seer");
        assert_eq!(p.seed, 42);
        assert!(!p.full);
    }

    #[test]
    fn job_spec_json_round_trips() {
        let specs = [
            JobSpec::Rollout(RolloutParams {
                task: "moonlight".into(),
                scheduler: "verl".into(),
                sd: "none".into(),
                seed: 7,
                bubble: 0.5,
                full: false,
            }),
            JobSpec::Sweep(SweepParams {
                task: "kimi-k2".into(),
                schedulers: vec!["seer".into(), "verl".into()],
                sd: "grouped-cst".into(),
                seeds: vec![1, 2, 3],
                full: false,
            }),
            JobSpec::Train(TrainParams {
                task: "moonlight".into(),
                scheduler: "seer".into(),
                sd: "grouped-cst".into(),
                iters: 4,
                seed: 9,
                drift: 0.1,
                mode: TrainingMode::Async { lag: 2 },
                cold: true,
                throttle_ms: 25,
                trainer_faults: FaultPlan::new()
                    .at(0.0, crate::sim::faults::FaultEvent::TrainerSlowdown {
                        factor: 2.0,
                        from: 10.0,
                        until: 20.0,
                    })
                    .at(0.0, crate::sim::faults::FaultEvent::TrainerCrash {
                        at_iter: 1,
                    }),
                full: false,
            }),
            JobSpec::Train(TrainParams {
                task: "moonlight".into(),
                scheduler: "seer".into(),
                sd: "grouped-cst".into(),
                iters: 2,
                seed: 3,
                drift: 0.0,
                mode: TrainingMode::Hybrid,
                cold: false,
                throttle_ms: 0,
                trainer_faults: FaultPlan::new(),
                full: false,
            }),
        ];
        for spec in specs {
            let j = Json::parse(&spec.to_json().to_string()).unwrap();
            assert_eq!(JobSpec::from_json(&j).unwrap(), spec);
        }
    }

    #[test]
    fn accepts_every_registered_scheduler_in_jobs() {
        // `check_policies` resolves through the builtin registry, so a
        // newly registered policy (e.g. rollpacker) must be submittable
        // both as a rollout job and inside a sweep's scheduler list
        // without touching the serve layer.
        for name in crate::rollout::PolicyRegistry::builtin()
            .scheduler_names()
        {
            let line = format!(
                r#"{{"verb":"submit","job":{{"kind":"rollout","scheduler":"{name}"}}}}"#
            );
            let r = Request::parse(&line).unwrap();
            let Request::Submit {
                spec: JobSpec::Rollout(p),
                ..
            } = r
            else {
                panic!("{name}: not a rollout submit")
            };
            assert_eq!(p.scheduler, name);
        }
        let r = Request::parse(
            r#"{"verb":"submit","job":{"kind":"sweep","schedulers":["seer","verl","streamrl","rollpacker"]}}"#,
        )
        .unwrap();
        let Request::Submit {
            spec: JobSpec::Sweep(p),
            ..
        } = r
        else {
            panic!("not a sweep submit")
        };
        assert_eq!(p.schedulers.len(), 4);
        assert_eq!(p.schedulers[3], "rollpacker");
    }

    #[test]
    fn rejects_bad_requests_with_reasons() {
        for (line, needle) in [
            ("nonsense", "parse"),
            ("[1,2]", "object"),
            (r#"{"x":1}"#, "verb"),
            (r#"{"verb":"frobnicate"}"#, "unknown verb"),
            (r#"{"verb":"result"}"#, "missing field 'job'"),
            (r#"{"verb":"result","job":"three"}"#, "must be a number"),
            (r#"{"verb":"submit"}"#, "'job'"),
            (r#"{"verb":"submit","job":{"kind":"bake"}}"#, "unknown job kind"),
            (
                r#"{"verb":"submit","job":{"kind":"rollout","task":"nope"}}"#,
                "unknown task",
            ),
            (
                r#"{"verb":"submit","job":{"kind":"rollout","scheduler":"bogus"}}"#,
                "bogus",
            ),
            (
                r#"{"verb":"submit","job":{"kind":"rollout","seed":"x"}}"#,
                "'seed'",
            ),
            (
                r#"{"verb":"submit","job":{"kind":"rollout","bubble":1.5}}"#,
                "bubble",
            ),
            (
                r#"{"verb":"submit","job":{"kind":"train","iters":0}}"#,
                "iters",
            ),
            (
                r#"{"verb":"submit","job":{"kind":"train","mode":"warp"}}"#,
                "unknown training mode",
            ),
            (
                r#"{"verb":"submit","job":{"kind":"train","mode":"sync","lag":2}}"#,
                "only applies",
            ),
            (
                r#"{"verb":"submit","job":{"kind":"sweep","schedulers":[]}}"#,
                "at least one",
            ),
            (r#"{"verb":"shutdown","mode":"maybe"}"#, "shutdown mode"),
            (
                r#"{"verb":"submit","job":{"kind":"train","deadline_secs":0}}"#,
                "deadline_secs",
            ),
            (
                r#"{"verb":"submit","job":{"kind":"train","deadline_secs":"soon"}}"#,
                "'deadline_secs' must be a number",
            ),
            (
                r#"{"verb":"submit","job":{"kind":"train","max_attempts":0}}"#,
                "max_attempts",
            ),
            (
                r#"{"verb":"submit","job":{"kind":"train","max_attempts":99}}"#,
                "max_attempts",
            ),
            (
                r#"{"verb":"submit","job":{"kind":"train","trainer_faults":7}}"#,
                "trainer_faults",
            ),
            (
                r#"{"verb":"submit","job":{"kind":"train","trainer_faults":{"events":[{"at_secs":1,"kind":"scale_up","n":1}]}}}"#,
                "trainer-side events only",
            ),
            (
                r#"{"verb":"submit","job":{"kind":"train","trainer_faults":{"events":[{"at_secs":0,"kind":"trainer_slowdown","factor":-1,"from":0,"until":1}]}}}"#,
                "trainer_faults",
            ),
        ] {
            let e = Request::parse(line).unwrap_err().to_string();
            assert!(
                e.to_lowercase().contains(&needle.to_lowercase()),
                "{line}: {e}"
            );
        }
    }

    #[test]
    fn job_control_fields_parse_from_the_submit_envelope() {
        let r = Request::parse(
            r#"{"verb":"submit","job":{"kind":"train","deadline_secs":1.5,"priority":3,"max_attempts":4}}"#,
        )
        .unwrap();
        let Request::Submit { control, spec, .. } = r else {
            panic!("not a submit")
        };
        assert_eq!(
            control,
            JobControl {
                deadline_secs: Some(1.5),
                priority: 3,
                max_attempts: 4,
            }
        );
        // Control fields never leak into the spec (nor, therefore, into
        // checkpoints): the same job without them parses identically.
        let again = Request::parse(r#"{"verb":"submit","job":{"kind":"train"}}"#)
            .unwrap();
        let Request::Submit { spec: bare, .. } = again else {
            panic!("not a submit")
        };
        assert_eq!(spec, bare);
    }

    #[test]
    fn trainer_fault_plans_ride_the_train_spec() {
        let r = Request::parse(
            r#"{"verb":"submit","job":{"kind":"train","trainer_faults":{"events":[{"at_secs":0,"kind":"trainer_stall","at":12.0,"secs":30.0},{"at_secs":0,"kind":"trainer_crash","at_iter":2}]}}}"#,
        )
        .unwrap();
        let Request::Submit {
            spec: JobSpec::Train(p),
            ..
        } = r
        else {
            panic!("not a train submit")
        };
        assert_eq!(p.trainer_faults.len(), 2);
        // The plan reaches the training config the executor runs.
        let cfg = p.training_config().unwrap();
        assert_eq!(cfg.trainer_faults, p.trainer_faults);
    }

    #[test]
    fn shutdown_modes() {
        assert_eq!(
            Request::parse(r#"{"verb":"shutdown"}"#).unwrap(),
            Request::Shutdown { abort: false }
        );
        assert_eq!(
            Request::parse(r#"{"verb":"shutdown","mode":"abort"}"#).unwrap(),
            Request::Shutdown { abort: true }
        );
    }

    #[test]
    fn replies_have_stable_shape() {
        let ok = ok_reply(vec![("job", Json::Num(3.0))]).to_string();
        assert_eq!(ok, r#"{"job":3,"ok":true}"#);
        let err = err_reply("quota", "full").to_string();
        assert_eq!(err, r#"{"code":"quota","error":"full","ok":false}"#);
    }

    #[test]
    fn train_report_is_deterministic_in_history() {
        let p = TrainParams {
            task: "moonlight".into(),
            scheduler: "seer".into(),
            sd: "grouped-cst".into(),
            iters: 1,
            seed: 1,
            drift: 0.0,
            mode: TrainingMode::Sync,
            cold: false,
            throttle_ms: 0,
            trainer_faults: FaultPlan::new(),
            full: false,
        };
        let mut d = crate::iteration::TrainingDriver::new(
            p.training_config().unwrap(),
        );
        let h = vec![d.run_iteration(0).unwrap()];
        assert_eq!(
            train_report(&p, &h).to_string(),
            train_report(&p, &h).to_string()
        );
        assert!(train_report(&p, &h)
            .get("final_p99_finish_secs")
            .is_some());
        // Sync runs report zero staleness — the fields still appear so
        // consumers can diff them across modes.
        assert_eq!(
            train_report(&p, &h)
                .get("total_stale_requests")
                .and_then(Json::as_u64),
            Some(0)
        );
    }
}
