//! Crash-durable train-job state.
//!
//! After every completed iteration the job executor snapshots the
//! driver's recoverable state — the job identity, its [`TrainParams`],
//! the per-iteration summaries so far, and the full [`ContextStore`] —
//! to `train_<id>.ckpt.json` in the daemon's state directory. The write
//! is atomic (temp file + rename), so a crash mid-write leaves the
//! previous checkpoint intact. On restart the server scans the
//! directory and re-queues every checkpointed job;
//! [`crate::iteration::TrainingDriver::with_resume`] then continues the
//! epoch sequence, and because every field round-trips through
//! [`crate::util::json`] exactly (shortest-roundtrip floats), the
//! resumed job's final report is byte-identical to an uninterrupted
//! run's. Checkpoints are deleted when their job completes, fails, or
//! is cancelled by a client (an *abort shutdown* retains them — that is
//! the recovery path).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::iteration::{ContextStore, IterationSummary};
use crate::util::json::Json;

use super::api::{JobSpec, TrainParams};

/// Everything needed to resume one interrupted train job.
#[derive(Debug, Clone)]
pub struct TrainCheckpoint {
    pub job_id: u64,
    pub tenant: String,
    pub params: TrainParams,
    /// Summaries of the iterations already completed, in order.
    pub history: Vec<IterationSummary>,
    pub store: ContextStore,
}

impl TrainCheckpoint {
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("job_id".to_string(), Json::Num(self.job_id as f64));
        o.insert("tenant".to_string(), Json::Str(self.tenant.clone()));
        o.insert(
            "params".to_string(),
            JobSpec::Train(self.params.clone()).to_json(),
        );
        o.insert(
            "history".to_string(),
            Json::Arr(self.history.iter().map(|s| s.to_json()).collect()),
        );
        o.insert("store".to_string(), self.store.to_json());
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<TrainCheckpoint> {
        let job_id = j
            .get("job_id")
            .and_then(Json::as_u64)
            .context("checkpoint: bad 'job_id'")?;
        let tenant = j
            .get("tenant")
            .and_then(Json::as_str)
            .context("checkpoint: bad 'tenant'")?
            .to_string();
        let params = match JobSpec::from_json(
            j.get("params").context("checkpoint: missing 'params'")?,
        )? {
            JobSpec::Train(p) => p,
            other => anyhow::bail!(
                "checkpoint: params is a {} job, not train",
                other.kind()
            ),
        };
        let history = j
            .get("history")
            .and_then(Json::as_arr)
            .context("checkpoint: bad 'history'")?
            .iter()
            .map(IterationSummary::from_json)
            .collect::<Result<Vec<_>>>()?;
        let store = ContextStore::from_json(
            j.get("store").context("checkpoint: missing 'store'")?,
        )?;
        Ok(TrainCheckpoint {
            job_id,
            tenant,
            params,
            history,
            store,
        })
    }

    /// `<dir>/train_<id>.ckpt.json`.
    pub fn path_for(dir: &Path, job_id: u64) -> PathBuf {
        dir.join(format!("train_{job_id}.ckpt.json"))
    }

    /// Atomically persist: write `.tmp`, then rename over the target.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir).with_context(|| {
            format!("creating checkpoint dir {}", dir.display())
        })?;
        let path = Self::path_for(dir, self.job_id);
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json().to_string())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<TrainCheckpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| {
            anyhow::anyhow!("checkpoint {}: {e}", path.display())
        })?;
        Self::from_json(&j)
    }

    /// Delete the checkpoint for `job_id`, if present.
    pub fn remove(dir: &Path, job_id: u64) -> Result<()> {
        let path = Self::path_for(dir, job_id);
        match std::fs::remove_file(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => {
                Err(e).with_context(|| format!("removing {}", path.display()))
            }
        }
    }

    /// All checkpoints in `dir`, sorted by job id. A missing directory
    /// is an empty recovery set; an unreadable *file* is an error — a
    /// daemon silently dropping a recoverable job is the one behavior
    /// this module exists to prevent.
    pub fn scan_dir(dir: &Path) -> Result<Vec<TrainCheckpoint>> {
        let entries = match std::fs::read_dir(dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(Vec::new())
            }
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("scanning {}", dir.display()))
            }
        };
        let mut out = Vec::new();
        for entry in entries {
            let path = entry?.path();
            let name = path
                .file_name()
                .and_then(|n| n.to_str())
                .unwrap_or_default();
            if name.starts_with("train_") && name.ends_with(".ckpt.json") {
                out.push(Self::load(&path)?);
            }
        }
        out.sort_by_key(|c| c.job_id);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iteration::TrainingDriver;

    fn params() -> TrainParams {
        TrainParams {
            task: "moonlight".into(),
            scheduler: "seer".into(),
            sd: "grouped-cst".into(),
            iters: 2,
            seed: 5,
            drift: 0.1,
            mode: crate::config::TrainingMode::Async { lag: 1 },
            cold: false,
            throttle_ms: 0,
            full: false,
        }
    }

    fn checkpoint_after_one_iteration() -> TrainCheckpoint {
        let p = params();
        let mut d = TrainingDriver::new(p.training_config().unwrap());
        d.run_iteration(0).unwrap();
        TrainCheckpoint {
            job_id: 3,
            tenant: "alice".into(),
            params: p,
            history: d.history().to_vec(),
            store: d.into_store(),
        }
    }

    #[test]
    fn save_load_round_trips_and_resumes() {
        let dir = std::env::temp_dir()
            .join(format!("seer-ckpt-test-{}", std::process::id()));
        let ckpt = checkpoint_after_one_iteration();
        ckpt.save(&dir).unwrap();
        // Save twice: the atomic tmp+rename path must be re-entrant.
        ckpt.save(&dir).unwrap();

        let scanned = TrainCheckpoint::scan_dir(&dir).unwrap();
        assert_eq!(scanned.len(), 1);
        let back = &scanned[0];
        assert_eq!(back.job_id, 3);
        assert_eq!(back.tenant, "alice");
        assert_eq!(back.params, ckpt.params);
        assert_eq!(back.history, ckpt.history);
        assert_eq!(back.store, ckpt.store);

        // The loaded state actually resumes: epoch numbering continues.
        let d = TrainingDriver::with_resume(
            back.params.training_config().unwrap(),
            back.store.clone(),
            back.history.clone(),
        )
        .unwrap();
        assert_eq!(d.next_epoch(), 1);

        TrainCheckpoint::remove(&dir, 3).unwrap();
        TrainCheckpoint::remove(&dir, 3).unwrap(); // idempotent
        assert!(TrainCheckpoint::scan_dir(&dir).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scan_of_missing_dir_is_empty() {
        let dir = std::env::temp_dir().join("seer-ckpt-never-created");
        assert!(TrainCheckpoint::scan_dir(&dir).unwrap().is_empty());
    }

    #[test]
    fn corrupt_checkpoint_is_an_error_not_a_skip() {
        let dir = std::env::temp_dir()
            .join(format!("seer-ckpt-corrupt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("train_9.ckpt.json"), "{\"job_id\":").unwrap();
        assert!(TrainCheckpoint::scan_dir(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
