//! Crash-durable train-job state.
//!
//! After every completed iteration the job executor snapshots the
//! driver's recoverable state — the job identity, its [`TrainParams`],
//! the per-iteration summaries so far, and the full [`ContextStore`] —
//! to `train_<id>.ckpt.json` in the daemon's state directory. The write
//! is atomic (temp file + rename), so a crash mid-write leaves the
//! previous checkpoint intact. On restart the server scans the
//! directory and re-queues every checkpointed job;
//! [`crate::iteration::TrainingDriver::with_resume`] then continues the
//! epoch sequence, and because every field round-trips through
//! [`crate::util::json`] exactly (shortest-roundtrip floats), the
//! resumed job's final report is byte-identical to an uninterrupted
//! run's. Checkpoints are deleted when their job completes, fails, or
//! is cancelled by a client (an *abort shutdown* retains them — that is
//! the recovery path).
//!
//! # Format v2: checksum + rotation
//!
//! A checkpoint file is a wrapper object `{"crc": "<fnv1a64 hex>",
//! "data": {…}, "v": 2}` where `crc` is the FNV-1a-64 checksum of the
//! canonical serialization of `data` (the v1 payload). Because
//! [`crate::util::json`] serialization is canonical (BTreeMap key
//! order, shortest-roundtrip floats), the verifier re-serializes the
//! parsed `data` and compares — any torn write, truncation, or bit
//! flip fails closed. Bare v1 objects (no `v` tag) are still accepted
//! on read.
//!
//! Each save *rotates*: the previous newest moves to
//! `train_<id>.ckpt.json.1`, `.1` to `.2`, …, keeping the last
//! [`TrainCheckpoint::DEFAULT_KEEP`] generations (configurable via
//! `serve --keep-ckpts`). Recovery ([`TrainCheckpoint::load_newest_valid`],
//! used by [`TrainCheckpoint::scan_dir`]) walks the generations newest
//! first and resumes from the first that verifies; a job is an error
//! only when *no* generation is valid — a daemon silently dropping a
//! recoverable job is still the one behavior this module exists to
//! prevent.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::iteration::{ContextStore, IterationSummary};
use crate::util::json::Json;

use super::api::{JobSpec, TrainParams};

/// FNV-1a 64-bit hash — the checkpoint integrity checksum. Chosen for
/// being a dozen lines of dependency-free code with good avalanche on
/// the torn-write / truncation corruptions checkpoints actually see;
/// this is an integrity check, not a cryptographic one.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Path of rotated generation `n` (1-based) for a base checkpoint path:
/// `train_<id>.ckpt.json.1`, `.2`, … Generation 0 is the base itself.
fn generation_path(base: &Path, n: usize) -> PathBuf {
    if n == 0 {
        base.to_path_buf()
    } else {
        let mut os = base.as_os_str().to_os_string();
        os.push(format!(".{n}"));
        PathBuf::from(os)
    }
}

/// Everything needed to resume one interrupted train job.
#[derive(Debug, Clone)]
pub struct TrainCheckpoint {
    pub job_id: u64,
    pub tenant: String,
    pub params: TrainParams,
    /// Summaries of the iterations already completed, in order.
    pub history: Vec<IterationSummary>,
    pub store: ContextStore,
}

impl TrainCheckpoint {
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("job_id".to_string(), Json::Num(self.job_id as f64));
        o.insert("tenant".to_string(), Json::Str(self.tenant.clone()));
        o.insert(
            "params".to_string(),
            JobSpec::Train(self.params.clone()).to_json(),
        );
        o.insert(
            "history".to_string(),
            Json::Arr(self.history.iter().map(|s| s.to_json()).collect()),
        );
        o.insert("store".to_string(), self.store.to_json());
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<TrainCheckpoint> {
        let job_id = j
            .get("job_id")
            .and_then(Json::as_u64)
            .context("checkpoint: bad 'job_id'")?;
        let tenant = j
            .get("tenant")
            .and_then(Json::as_str)
            .context("checkpoint: bad 'tenant'")?
            .to_string();
        let params = match JobSpec::from_json(
            j.get("params").context("checkpoint: missing 'params'")?,
        )? {
            JobSpec::Train(p) => p,
            other => anyhow::bail!(
                "checkpoint: params is a {} job, not train",
                other.kind()
            ),
        };
        let history = j
            .get("history")
            .and_then(Json::as_arr)
            .context("checkpoint: bad 'history'")?
            .iter()
            .map(IterationSummary::from_json)
            .collect::<Result<Vec<_>>>()?;
        let store = ContextStore::from_json(
            j.get("store").context("checkpoint: missing 'store'")?,
        )?;
        Ok(TrainCheckpoint {
            job_id,
            tenant,
            params,
            history,
            store,
        })
    }

    /// Generations kept per job by default: the live file plus two
    /// rotated predecessors.
    pub const DEFAULT_KEEP: usize = 3;

    /// `<dir>/train_<id>.ckpt.json` — always the *newest* generation,
    /// so existence checks and external tooling need no rotation logic.
    pub fn path_for(dir: &Path, job_id: u64) -> PathBuf {
        dir.join(format!("train_{job_id}.ckpt.json"))
    }

    /// Serialize as the v2 wrapper: checksum over the canonical `data`
    /// serialization, so any truncation or bit flip fails closed on read.
    fn wrap(&self) -> String {
        let data = self.to_json().to_string();
        let mut o = BTreeMap::new();
        o.insert(
            "crc".to_string(),
            Json::Str(format!("{:016x}", fnv1a64(data.as_bytes()))),
        );
        o.insert("data".to_string(), self.to_json());
        o.insert("v".to_string(), Json::Num(2.0));
        Json::Obj(o).to_string()
    }

    /// Persist with rotation, keeping [`Self::DEFAULT_KEEP`] generations.
    pub fn save(&self, dir: &Path) -> Result<()> {
        self.save_rotating(dir, Self::DEFAULT_KEEP)
    }

    /// Atomically persist: write `.tmp`, shift prior generations one
    /// slot down (dropping any past `keep - 1`), then rename over the
    /// base path. A crash at any point leaves every surviving
    /// generation either fully old or fully new — never torn.
    pub fn save_rotating(&self, dir: &Path, keep: usize) -> Result<()> {
        let keep = keep.max(1);
        std::fs::create_dir_all(dir).with_context(|| {
            format!("creating checkpoint dir {}", dir.display())
        })?;
        let path = Self::path_for(dir, self.job_id);
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.wrap())
            .with_context(|| format!("writing {}", tmp.display()))?;
        for n in (1..keep).rev() {
            let from = generation_path(&path, n - 1);
            if from.exists() {
                std::fs::rename(&from, generation_path(&path, n))
                    .with_context(|| {
                        format!("rotating {}", from.display())
                    })?;
            }
        }
        // Trim anything beyond the cap (e.g. after lowering --keep-ckpts).
        let mut n = keep;
        while generation_path(&path, n).exists() {
            let _ = std::fs::remove_file(generation_path(&path, n));
            n += 1;
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }

    /// Parse and *verify* one generation file. v2 wrappers must pass
    /// the checksum; bare v1 objects are accepted unverified.
    pub fn load(path: &Path) -> Result<TrainCheckpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| {
            anyhow::anyhow!("checkpoint {}: {e}", path.display())
        })?;
        let data = match j.get("v").and_then(Json::as_u64) {
            Some(2) => {
                let crc = j.get("crc").and_then(Json::as_str).with_context(
                    || format!("checkpoint {}: missing 'crc'", path.display()),
                )?;
                let data = j.get("data").with_context(|| {
                    format!("checkpoint {}: missing 'data'", path.display())
                })?;
                let actual =
                    format!("{:016x}", fnv1a64(data.to_string().as_bytes()));
                if actual != crc {
                    anyhow::bail!(
                        "checkpoint {}: checksum mismatch (recorded {crc}, \
                         computed {actual})",
                        path.display()
                    );
                }
                data.clone()
            }
            _ => j, // v1: bare payload, no checksum to verify.
        };
        Self::from_json(&data)
    }

    /// Walk generations newest-first and return the first that
    /// verifies. Errors only when every existing generation is
    /// corrupt (or none exists).
    pub fn load_newest_valid(path: &Path) -> Result<TrainCheckpoint> {
        let mut errs = Vec::new();
        let mut n = 0usize;
        loop {
            let gen = generation_path(path, n);
            if n > 0 && !gen.exists() {
                break;
            }
            match Self::load(&gen) {
                Ok(c) => return Ok(c),
                Err(e) => errs.push(format!("{e:#}")),
            }
            n += 1;
        }
        anyhow::bail!(
            "no valid checkpoint generation for {}: {}",
            path.display(),
            errs.join("; ")
        )
    }

    /// Delete every generation of the checkpoint for `job_id`.
    pub fn remove(dir: &Path, job_id: u64) -> Result<()> {
        let path = Self::path_for(dir, job_id);
        let mut n = 0usize;
        loop {
            let gen = generation_path(&path, n);
            match std::fs::remove_file(&gen) {
                Ok(()) => {}
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                    if n > 0 {
                        return Ok(());
                    }
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!("removing {}", gen.display())
                    })
                }
            }
            n += 1;
        }
    }

    /// All checkpoints in `dir`, sorted by job id, each recovered from
    /// its newest valid generation. A missing directory is an empty
    /// recovery set; a job whose every generation is unreadable is an
    /// error — a daemon silently dropping a recoverable job is the one
    /// behavior this module exists to prevent.
    pub fn scan_dir(dir: &Path) -> Result<Vec<TrainCheckpoint>> {
        let entries = match std::fs::read_dir(dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(Vec::new())
            }
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("scanning {}", dir.display()))
            }
        };
        let mut out = Vec::new();
        for entry in entries {
            let path = entry?.path();
            let name = path
                .file_name()
                .and_then(|n| n.to_str())
                .unwrap_or_default();
            // Rotated generations end in `.ckpt.json.<n>` and are
            // reached through their base file, not enumerated here.
            if name.starts_with("train_") && name.ends_with(".ckpt.json") {
                out.push(Self::load_newest_valid(&path)?);
            }
        }
        out.sort_by_key(|c| c.job_id);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iteration::TrainingDriver;

    fn params() -> TrainParams {
        TrainParams {
            task: "moonlight".into(),
            scheduler: "seer".into(),
            sd: "grouped-cst".into(),
            iters: 2,
            seed: 5,
            drift: 0.1,
            mode: crate::config::TrainingMode::Async { lag: 1 },
            cold: false,
            throttle_ms: 0,
            full: false,
            trainer_faults: crate::sim::faults::FaultPlan::new(),
        }
    }

    fn checkpoint_after_one_iteration() -> TrainCheckpoint {
        let p = params();
        let mut d = TrainingDriver::new(p.training_config().unwrap());
        d.run_iteration(0).unwrap();
        TrainCheckpoint {
            job_id: 3,
            tenant: "alice".into(),
            params: p,
            history: d.history().to_vec(),
            store: d.into_store(),
        }
    }

    #[test]
    fn save_load_round_trips_and_resumes() {
        let dir = std::env::temp_dir()
            .join(format!("seer-ckpt-test-{}", std::process::id()));
        let ckpt = checkpoint_after_one_iteration();
        ckpt.save(&dir).unwrap();
        // Save twice: the atomic tmp+rename path must be re-entrant.
        ckpt.save(&dir).unwrap();

        let scanned = TrainCheckpoint::scan_dir(&dir).unwrap();
        assert_eq!(scanned.len(), 1);
        let back = &scanned[0];
        assert_eq!(back.job_id, 3);
        assert_eq!(back.tenant, "alice");
        assert_eq!(back.params, ckpt.params);
        assert_eq!(back.history, ckpt.history);
        assert_eq!(back.store, ckpt.store);

        // The loaded state actually resumes: epoch numbering continues.
        let d = TrainingDriver::with_resume(
            back.params.training_config().unwrap(),
            back.store.clone(),
            back.history.clone(),
        )
        .unwrap();
        assert_eq!(d.next_epoch(), 1);

        TrainCheckpoint::remove(&dir, 3).unwrap();
        TrainCheckpoint::remove(&dir, 3).unwrap(); // idempotent
        assert!(TrainCheckpoint::scan_dir(&dir).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scan_of_missing_dir_is_empty() {
        let dir = std::env::temp_dir().join("seer-ckpt-never-created");
        assert!(TrainCheckpoint::scan_dir(&dir).unwrap().is_empty());
    }

    #[test]
    fn fully_corrupt_checkpoint_is_an_error_not_a_skip() {
        let dir = std::env::temp_dir()
            .join(format!("seer-ckpt-corrupt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Sole generation is truncated: there is nothing valid to fall
        // back to, so recovery must refuse rather than drop the job.
        std::fs::write(dir.join("train_9.ckpt.json"), "{\"job_id\":").unwrap();
        assert!(TrainCheckpoint::scan_dir(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checksum_rejects_bit_flips_that_still_parse() {
        let dir = std::env::temp_dir()
            .join(format!("seer-ckpt-crc-{}", std::process::id()));
        let ckpt = checkpoint_after_one_iteration();
        ckpt.save(&dir).unwrap();
        let path = TrainCheckpoint::path_for(&dir, 3);
        let text = std::fs::read_to_string(&path).unwrap();
        // Corrupt the payload without breaking JSON syntax: a bare v1
        // parser would accept this silently.
        let flipped = text.replacen("\"tenant\":\"alice\"", "\"tenant\":\"mallory\"", 1);
        assert_ne!(flipped, text, "fixture must actually change");
        std::fs::write(&path, flipped).unwrap();
        let err = TrainCheckpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "got: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Writes `n` successive checkpoints of the same job (one more
    /// completed iteration each), returning the final driver history
    /// length per saved generation for later assertions.
    fn save_generations(dir: &Path, n: usize) -> Vec<usize> {
        let mut p = params();
        p.iters = n + 1;
        let mut d = TrainingDriver::new(p.training_config().unwrap());
        let mut lens = Vec::new();
        for e in 0..n {
            d.run_iteration(e).unwrap();
            let ckpt = TrainCheckpoint {
                job_id: 7,
                tenant: "alice".into(),
                params: p.clone(),
                history: d.history().to_vec(),
                store: d.store().clone(),
            };
            ckpt.save(dir).unwrap();
            lens.push(d.history().len());
        }
        lens
    }

    #[test]
    fn rotation_keeps_last_k_and_falls_back_to_newest_valid() {
        let dir = std::env::temp_dir()
            .join(format!("seer-ckpt-rotate-{}", std::process::id()));
        let k = TrainCheckpoint::DEFAULT_KEEP;
        let lens = save_generations(&dir, k + 2);
        let base = TrainCheckpoint::path_for(&dir, 7);

        // Exactly K generations survive: base (newest) plus .1 … .(K-1).
        assert!(base.exists());
        for n in 1..k {
            assert!(generation_path(&base, n).exists(), "gen {n} missing");
        }
        assert!(!generation_path(&base, k).exists(), "gen {k} not trimmed");

        // Newest generation holds the most iterations; untouched, the
        // fallback loader returns it.
        let newest = TrainCheckpoint::load_newest_valid(&base).unwrap();
        assert_eq!(newest.history.len(), lens[k + 1]);

        // Truncate the newest at several offsets — mid-document, a few
        // bytes in, and to zero length — and corrupt the recorded
        // checksum; every variant must fall back to generation .1.
        let pristine = std::fs::read_to_string(&base).unwrap();
        let cuts = [0, 1, 7, pristine.len() / 2, pristine.len() - 1];
        for &cut in &cuts {
            std::fs::write(&base, &pristine[..cut]).unwrap();
            let back = TrainCheckpoint::load_newest_valid(&base).unwrap();
            assert_eq!(back.history.len(), lens[k], "truncated at {cut}");
        }
        let bad_crc = pristine.replacen("{\"crc\":\"", "{\"crc\":\"0", 1);
        std::fs::write(&base, &bad_crc).unwrap();
        let back = TrainCheckpoint::load_newest_valid(&base).unwrap();
        assert_eq!(back.history.len(), lens[k]);

        // scan_dir recovers through the same fallback, and the resumed
        // driver continues the epoch sequence where that generation
        // left off.
        let scanned = TrainCheckpoint::scan_dir(&dir).unwrap();
        assert_eq!(scanned.len(), 1);
        assert_eq!(scanned[0].history.len(), lens[k]);
        let d = TrainingDriver::with_resume(
            scanned[0].params.training_config().unwrap(),
            scanned[0].store.clone(),
            scanned[0].history.clone(),
        )
        .unwrap();
        assert_eq!(d.next_epoch(), lens[k]);

        // Corrupt every surviving generation: now recovery must error.
        for n in 1..k {
            std::fs::write(generation_path(&base, n), "<>").unwrap();
        }
        assert!(TrainCheckpoint::load_newest_valid(&base).is_err());
        assert!(TrainCheckpoint::scan_dir(&dir).is_err());

        // remove() clears every generation, corrupt or not.
        TrainCheckpoint::remove(&dir, 7).unwrap();
        assert!(!base.exists());
        for n in 1..k {
            assert!(!generation_path(&base, n).exists());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bare_v1_checkpoints_still_load() {
        let dir = std::env::temp_dir()
            .join(format!("seer-ckpt-v1-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt = checkpoint_after_one_iteration();
        let path = TrainCheckpoint::path_for(&dir, ckpt.job_id);
        std::fs::write(&path, ckpt.to_json().to_string()).unwrap();
        let back = TrainCheckpoint::load(&path).unwrap();
        assert_eq!(back.history, ckpt.history);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
