//! The job queue, lifecycle state machine, and executors.
//!
//! [`JobManager`] is the daemon's shared state: a monotonically
//! numbered job table behind one mutex/condvar pair. Connection
//! handlers call [`submit`](JobManager::submit) /
//! [`status_json`](JobManager::status_json) /
//! [`result_json`](JobManager::result_json) /
//! [`cancel_json`](JobManager::cancel_json); the
//! [`crate::sweep::SweepRunner`] worker pool calls
//! [`worker_loop`](JobManager::worker_loop). Every job carries a
//! [`CancelToken`] (checked at sweep-cell / train-iteration
//! granularity) and an [`EventMux`] so any number of `subscribe`
//! connections can watch it live.
//!
//! Lifecycle: `queued → running → done | failed | cancelled |
//! deadline-exceeded | shed` (queued jobs may cancel — or be shed —
//! directly). Train jobs additionally checkpoint after every iteration
//! ([`TrainCheckpoint`]); an abort shutdown leaves the checkpoint on
//! disk, and [`JobManager::new`] re-queues whatever it finds there —
//! that pair is the kill-then-restart recovery path.
//!
//! Supervision (PR 10): each job carries a [`JobControl`] — a
//! wall-clock `deadline_secs` enforced at the existing cancellation
//! points, a `priority` that overload shedding consults when the
//! *global* cap denies a submit, and a `max_attempts` retry budget
//! replayed with the deterministic [`RetryPolicy`] backoff. Wall-clock
//! touches supervision decisions only — never a report, which stays a
//! pure function of the spec.

use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::iteration::TrainingDriver;
use crate::rollout::EventMux;
use crate::sweep::{CancelToken, SweepRunner};
use crate::util::json::Json;

use super::api::{self, JobControl, JobSpec};
use super::checkpoint::TrainCheckpoint;
use super::log;
use super::quota::{QuotaConfig, QuotaDenied};
use super::retry::{is_retryable, RetryPolicy};

/// Where a job is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
    /// The wall-clock `deadline_secs` budget ran out at a cancellation
    /// point.
    DeadlineExceeded,
    /// Evicted while queued to admit a higher-priority job under
    /// global-cap pressure.
    Shed,
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
            JobState::DeadlineExceeded => "deadline-exceeded",
            JobState::Shed => "shed",
        }
    }

    /// Terminal states never transition again.
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }
}

/// How one execution ended (errors travel separately as `Result`).
enum Outcome {
    Done(Json),
    Cancelled,
    /// Deadline hit; carries the human reason for status/result.
    DeadlineExceeded(String),
}

struct Job {
    id: u64,
    tenant: String,
    spec: JobSpec,
    control: JobControl,
    state: JobState,
    result: Option<Json>,
    error: Option<String>,
    cancel: CancelToken,
    mux: EventMux,
    /// Train jobs: (iterations done, iterations total).
    progress: Option<(usize, usize)>,
    /// Execution attempts started so far (1 = first run, no retry yet).
    attempts: u64,
    /// Re-queued from an on-disk checkpoint at daemon start.
    recovered: bool,
}

#[derive(Default)]
struct Inner {
    jobs: BTreeMap<u64, Job>,
    queue: VecDeque<u64>,
    next_id: u64,
}

impl Inner {
    fn in_flight(&self) -> usize {
        self.jobs
            .values()
            .filter(|job| !job.state.is_terminal())
            .count()
    }

    fn tenant_in_flight(&self, tenant: &str) -> usize {
        self.jobs
            .values()
            .filter(|job| job.tenant == tenant && !job.state.is_terminal())
            .count()
    }
}

/// The daemon's shared job table + queue. All methods are `&self`; the
/// manager is designed to sit behind an `Arc` shared by the acceptor,
/// the connection handlers, and the worker pool.
pub struct JobManager {
    inner: Mutex<Inner>,
    cv: Condvar,
    quota: QuotaConfig,
    state_dir: Option<PathBuf>,
    retry: RetryPolicy,
    /// Checkpoint generations kept per train job (`--keep-ckpts`).
    keep_ckpts: usize,
    shutdown: AtomicBool,
    abort: AtomicBool,
}

impl JobManager {
    /// Create the manager, recovering any train-job checkpoints found in
    /// `state_dir` as freshly queued jobs (same ids; `next_id` continues
    /// past them).
    pub fn new(
        quota: QuotaConfig,
        state_dir: Option<PathBuf>,
    ) -> Result<JobManager> {
        let mut inner = Inner {
            next_id: 1,
            ..Inner::default()
        };
        if let Some(dir) = &state_dir {
            for ck in TrainCheckpoint::scan_dir(dir)? {
                log::info(
                    "jobs",
                    format!(
                        "recovered job {} (tenant '{}', {}/{} iterations \
                         done) from checkpoint",
                        ck.job_id,
                        ck.tenant,
                        ck.history.len(),
                        ck.params.iters
                    ),
                );
                inner.next_id = inner.next_id.max(ck.job_id + 1);
                inner.queue.push_back(ck.job_id);
                inner.jobs.insert(
                    ck.job_id,
                    Job {
                        id: ck.job_id,
                        tenant: ck.tenant.clone(),
                        progress: Some((ck.history.len(), ck.params.iters)),
                        spec: JobSpec::Train(ck.params),
                        // Control knobs are not checkpointed: a
                        // recovered job runs unbounded and unranked —
                        // the recovered run *is* the retry.
                        control: JobControl::default(),
                        state: JobState::Queued,
                        result: None,
                        error: None,
                        cancel: CancelToken::new(),
                        mux: EventMux::new(),
                        attempts: 0,
                        recovered: true,
                    },
                );
            }
        }
        Ok(JobManager {
            inner: Mutex::new(inner),
            cv: Condvar::new(),
            quota,
            state_dir,
            retry: RetryPolicy::default(),
            keep_ckpts: TrainCheckpoint::DEFAULT_KEEP,
            shutdown: AtomicBool::new(false),
            abort: AtomicBool::new(false),
        })
    }

    /// Replace the retry backoff policy (daemon-wide; seeded, so two
    /// daemons configured alike schedule identical retries).
    pub fn with_retry_policy(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Checkpoint generations kept per train job (min 1).
    pub fn with_keep_ckpts(mut self, keep: usize) -> Self {
        self.keep_ckpts = keep.max(1);
        self
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        // Job state is plain data: a panicking worker must not wedge
        // every subsequent request into a poison error.
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// True once a shutdown was requested *and* no job is queued or
    /// running — the accept loop's exit condition.
    pub fn drained(&self) -> bool {
        self.is_shutdown() && self.lock().in_flight() == 0
    }

    /// Under global-cap pressure, evict the most shed-worthy *queued*
    /// job of strictly lower priority than `priority`: lowest priority
    /// first, newest (highest id) among ties — the cheapest promise to
    /// break. Returns the shed id, or `None` if nothing qualifies.
    fn shed_for(&self, g: &mut Inner, priority: u64) -> Option<u64> {
        let victim = g
            .jobs
            .values()
            .filter(|j| {
                j.state == JobState::Queued && j.control.priority < priority
            })
            .min_by_key(|j| (j.control.priority, std::cmp::Reverse(j.id)))?
            .id;
        let job = g.jobs.get_mut(&victim).expect("victim job");
        job.state = JobState::Shed;
        job.error = Some(format!(
            "shed while queued: global cap reached and a priority-{priority} \
             job arrived (this job's priority: {})",
            job.control.priority
        ));
        job.cancel.cancel();
        job.mux.close();
        if let Some(dir) = &self.state_dir {
            let _ = TrainCheckpoint::remove(dir, victim);
        }
        log::warn("jobs", format!("job {victim}: shed under overload"));
        Some(victim)
    }

    /// Admission control + enqueue. `Err` is a ready-to-send reply.
    pub fn submit(
        &self,
        tenant: &str,
        spec: JobSpec,
        control: JobControl,
    ) -> Result<u64, Json> {
        if self.is_shutdown() {
            return Err(api::err_reply(
                "shutting-down",
                "daemon is shutting down; not accepting jobs",
            ));
        }
        let mut g = self.lock();
        if let Err(denied) =
            self.quota
                .admit(tenant, g.tenant_in_flight(tenant), g.in_flight())
        {
            match denied {
                // Overload may be relieved by shedding a strictly
                // lower-priority queued job — but never on behalf of a
                // tenant its own cap would deny anyway; re-run
                // admission after, so both caps still bind.
                QuotaDenied::GlobalCap(_)
                    if g.tenant_in_flight(tenant)
                        < self.quota.max_per_tenant
                        && self.shed_for(&mut g, control.priority).is_some() =>
                {
                    if let Err(denied) = self.quota.admit(
                        tenant,
                        g.tenant_in_flight(tenant),
                        g.in_flight(),
                    ) {
                        drop(g);
                        self.cv.notify_all();
                        return Err(api::err_reply(
                            "quota",
                            denied.reason(),
                        ));
                    }
                }
                denied => {
                    return Err(api::err_reply("quota", denied.reason()))
                }
            }
        }
        let id = g.next_id;
        g.next_id += 1;
        let progress = match &spec {
            JobSpec::Train(p) => Some((0, p.iters)),
            _ => None,
        };
        log::info(
            "jobs",
            format!("job {id}: submitted ({} by '{tenant}')", spec.kind()),
        );
        g.jobs.insert(
            id,
            Job {
                id,
                tenant: tenant.to_string(),
                spec,
                control,
                state: JobState::Queued,
                result: None,
                error: None,
                cancel: CancelToken::new(),
                mux: EventMux::new(),
                progress,
                attempts: 0,
                recovered: false,
            },
        );
        g.queue.push_back(id);
        drop(g);
        self.cv.notify_all();
        Ok(id)
    }

    fn job_status_json(job: &Job) -> Json {
        let mut fields = vec![
            ("job", Json::Num(job.id as f64)),
            ("tenant", Json::Str(job.tenant.clone())),
            ("kind", Json::Str(job.spec.kind().to_string())),
            ("state", Json::Str(job.state.name().to_string())),
            ("attempts", Json::Num(job.attempts as f64)),
            ("recovered", Json::Bool(job.recovered)),
        ];
        if let Some((done, total)) = job.progress {
            let mut p = BTreeMap::new();
            p.insert("iters_done".to_string(), Json::Num(done as f64));
            p.insert("iters_total".to_string(), Json::Num(total as f64));
            fields.push(("progress", Json::Obj(p)));
        }
        if let Some(e) = &job.error {
            fields.push(("error", Json::Str(e.clone())));
        }
        api::ok_reply(fields)
    }

    /// Status of one job, or a whole-daemon summary with no id.
    pub fn status_json(&self, job: Option<u64>) -> Json {
        let g = self.lock();
        match job {
            Some(id) => match g.jobs.get(&id) {
                Some(job) => Self::job_status_json(job),
                None => api::err_reply("not-found", &format!("no job {id}")),
            },
            None => {
                let count = |s: JobState| {
                    Json::Num(
                        g.jobs.values().filter(|j| j.state == s).count() as f64,
                    )
                };
                api::ok_reply(vec![
                    ("jobs", Json::Num(g.jobs.len() as f64)),
                    ("queued", count(JobState::Queued)),
                    ("running", count(JobState::Running)),
                    ("done", count(JobState::Done)),
                    ("failed", count(JobState::Failed)),
                    ("cancelled", count(JobState::Cancelled)),
                    ("deadline_exceeded", count(JobState::DeadlineExceeded)),
                    ("shed", count(JobState::Shed)),
                    ("shutting_down", Json::Bool(self.is_shutdown())),
                ])
            }
        }
    }

    /// Block until the job is terminal, then reply with its result
    /// (`done`), error (`failed`), or cancellation.
    pub fn result_json(&self, id: u64) -> Json {
        let mut g = self.lock();
        loop {
            let Some(job) = g.jobs.get(&id) else {
                return api::err_reply("not-found", &format!("no job {id}"));
            };
            match job.state {
                JobState::Done => {
                    return api::ok_reply(vec![
                        ("job", Json::Num(id as f64)),
                        ("state", Json::Str("done".to_string())),
                        ("attempts", Json::Num(job.attempts as f64)),
                        (
                            "result",
                            job.result.clone().unwrap_or(Json::Null),
                        ),
                    ])
                }
                JobState::Failed => {
                    return api::err_reply(
                        "job-failed",
                        job.error.as_deref().unwrap_or("job failed"),
                    )
                }
                JobState::Cancelled => {
                    return api::err_reply(
                        "cancelled",
                        &format!("job {id} was cancelled"),
                    )
                }
                JobState::DeadlineExceeded => {
                    return api::err_reply(
                        "deadline-exceeded",
                        job.error.as_deref().unwrap_or("deadline exceeded"),
                    )
                }
                JobState::Shed => {
                    return api::err_reply(
                        "shed",
                        job.error.as_deref().unwrap_or(
                            "shed while queued under overload",
                        ),
                    )
                }
                JobState::Queued | JobState::Running => {
                    let (g2, _) = self
                        .cv
                        .wait_timeout(g, Duration::from_millis(100))
                        .unwrap_or_else(|e| e.into_inner());
                    g = g2;
                }
            }
        }
    }

    /// Cancel a job: queued jobs cancel immediately (and drop their
    /// checkpoint — the client asked for the job to *go away*), running
    /// jobs get their token set and transition when the executor reaches
    /// its next cancellation point. Terminal jobs are a no-op reply.
    pub fn cancel_json(&self, id: u64) -> Json {
        let mut g = self.lock();
        let Some(job) = g.jobs.get_mut(&id) else {
            return api::err_reply("not-found", &format!("no job {id}"));
        };
        match job.state {
            JobState::Queued => {
                job.state = JobState::Cancelled;
                job.cancel.cancel();
                job.mux.close();
                if let Some(dir) = &self.state_dir {
                    let _ = TrainCheckpoint::remove(dir, id);
                }
                drop(g);
                self.cv.notify_all();
                log::info("jobs", format!("job {id}: cancelled while queued"));
                api::ok_reply(vec![
                    ("job", Json::Num(id as f64)),
                    ("state", Json::Str("cancelled".to_string())),
                ])
            }
            JobState::Running => {
                job.cancel.cancel();
                log::info("jobs", format!("job {id}: cancellation requested"));
                api::ok_reply(vec![
                    ("job", Json::Num(id as f64)),
                    ("state", Json::Str("running".to_string())),
                    ("cancelling", Json::Bool(true)),
                ])
            }
            terminal => api::ok_reply(vec![
                ("job", Json::Num(id as f64)),
                ("state", Json::Str(terminal.name().to_string())),
            ]),
        }
    }

    /// The job's event mux, for `subscribe` connections.
    pub fn mux_of(&self, id: u64) -> Option<EventMux> {
        self.lock().jobs.get(&id).map(|j| j.mux.clone())
    }

    pub fn state_of(&self, id: u64) -> Option<JobState> {
        self.lock().jobs.get(&id).map(|j| j.state)
    }

    /// Begin shutdown: stop admitting, and either let admitted jobs
    /// drain (`abort == false`) or cancel them at their next
    /// cancellation point — retaining train checkpoints so a restarted
    /// daemon resumes them.
    pub fn request_shutdown(&self, abort: bool) {
        self.shutdown.store(true, Ordering::Release);
        if abort {
            self.abort.store(true, Ordering::Release);
            let mut g = self.lock();
            g.queue.clear();
            for job in g.jobs.values_mut() {
                match job.state {
                    JobState::Queued => {
                        job.state = JobState::Cancelled;
                        job.cancel.cancel();
                        job.mux.close();
                    }
                    JobState::Running => job.cancel.cancel(),
                    _ => {}
                }
            }
        }
        self.cv.notify_all();
        log::info(
            "jobs",
            format!(
                "shutdown requested ({})",
                if abort { "abort" } else { "graceful" }
            ),
        );
    }

    fn set_progress(&self, id: u64, done: usize, total: usize) {
        if let Some(job) = self.lock().jobs.get_mut(&id) {
            job.progress = Some((done, total));
        }
        self.cv.notify_all();
    }

    /// One worker's service loop: pop → run → record, until shutdown
    /// (graceful: after the queue drains; abort: immediately). Runs on
    /// the [`SweepRunner`] scoped worker pool — see
    /// [`crate::serve::server::Server::run`].
    pub fn worker_loop(&self, worker_id: usize) {
        loop {
            let (id, spec, control, cancel, mux, tenant) = {
                let mut g = self.lock();
                loop {
                    // Skip queue entries whose job was cancelled (or
                    // shed) while queued — both leave the id in the
                    // deque.
                    match g.queue.pop_front() {
                        Some(id) => {
                            let job = g.jobs.get_mut(&id).expect("queued job");
                            if job.state != JobState::Queued {
                                continue;
                            }
                            job.state = JobState::Running;
                            break (
                                id,
                                job.spec.clone(),
                                job.control,
                                job.cancel.clone(),
                                job.mux.clone(),
                                job.tenant.clone(),
                            );
                        }
                        None => {
                            if self.is_shutdown() {
                                return;
                            }
                            g = self
                                .cv
                                .wait(g)
                                .unwrap_or_else(|e| e.into_inner());
                        }
                    }
                }
            };
            log::info(
                "jobs",
                format!(
                    "job {id}: running {} on worker {worker_id}",
                    spec.kind()
                ),
            );
            // The deadline clock starts when the job starts *running* —
            // queue wait is the daemon's fault, not the job's.
            let deadline = control.deadline_secs.map(|s| {
                std::time::Instant::now() + Duration::from_secs_f64(s)
            });
            // Attempt loop: retryable failures re-run (resuming from
            // the job's own checkpoint where one exists) after a
            // deterministic backoff, until the budget is spent.
            let mut attempt = 0u64;
            let outcome = loop {
                attempt += 1;
                if let Some(job) = self.lock().jobs.get_mut(&id) {
                    job.attempts = attempt;
                }
                self.cv.notify_all();
                match self.execute(id, &spec, &cancel, &mux, &tenant, deadline)
                {
                    Ok(o) => break Ok(o),
                    Err(e) => {
                        let budget_left = attempt < control.max_attempts;
                        if !budget_left
                            || !is_retryable(&e)
                            || cancel.is_cancelled()
                        {
                            break Err(e);
                        }
                        let delay = self.retry.delay_ms(id, attempt);
                        log::warn(
                            "jobs",
                            format!(
                                "job {id}: attempt {attempt}/{} failed \
                                 retryably ({e:#}); retrying in {delay} ms",
                                control.max_attempts
                            ),
                        );
                        std::thread::sleep(Duration::from_millis(delay));
                    }
                }
            };
            let mut g = self.lock();
            let job = g.jobs.get_mut(&id).expect("running job");
            match outcome {
                Ok(Outcome::Done(result)) => {
                    job.state = JobState::Done;
                    job.result = Some(result);
                    log::info("jobs", format!("job {id}: done"));
                }
                Ok(Outcome::Cancelled) => {
                    job.state = JobState::Cancelled;
                    log::info("jobs", format!("job {id}: cancelled"));
                }
                Ok(Outcome::DeadlineExceeded(msg)) => {
                    job.state = JobState::DeadlineExceeded;
                    log::warn("jobs", format!("job {id}: {msg}"));
                    job.error = Some(msg);
                }
                Err(e) => {
                    job.state = JobState::Failed;
                    job.error = Some(format!("{e:#}"));
                    log::warn("jobs", format!("job {id}: failed: {e:#}"));
                }
            }
            job.mux.close();
            drop(g);
            self.cv.notify_all();
        }
    }

    /// The deadline message if `deadline` has passed, else `None`.
    /// Wall-clock is consulted here and nowhere else in the job path.
    fn deadline_hit(
        id: u64,
        deadline: Option<std::time::Instant>,
    ) -> Option<String> {
        match deadline {
            Some(d) if std::time::Instant::now() >= d => Some(format!(
                "job {id}: wall-clock deadline exceeded at a cancellation \
                 point"
            )),
            _ => None,
        }
    }

    fn execute(
        &self,
        id: u64,
        spec: &JobSpec,
        cancel: &CancelToken,
        mux: &EventMux,
        tenant: &str,
        deadline: Option<std::time::Instant>,
    ) -> Result<Outcome> {
        if cancel.is_cancelled() {
            return Ok(Outcome::Cancelled);
        }
        // Rollout and sweep jobs check the deadline at their start (and
        // train jobs at every iteration); a result that *finishes*
        // before anyone looks again is returned, not discarded.
        if let Some(msg) = Self::deadline_hit(id, deadline) {
            return Ok(Outcome::DeadlineExceeded(msg));
        }
        match spec {
            JobSpec::Rollout(p) => {
                let report = p
                    .session()?
                    .observer(Box::new(mux.clone()))
                    .run()?;
                Ok(Outcome::Done(report.to_json()))
            }
            JobSpec::Sweep(p) => {
                // Serial inner runner: parallelism across *jobs* belongs
                // to the worker pool; nesting pools would oversubscribe.
                let outcome =
                    SweepRunner::new(1).run_with_cancel(&p.sweep_spec()?, cancel);
                match outcome {
                    Ok(o) => Ok(Outcome::Done(o.report.to_json())),
                    Err(_) if cancel.is_cancelled() => Ok(Outcome::Cancelled),
                    Err(e) => Err(e),
                }
            }
            JobSpec::Train(p) => {
                self.execute_train(id, p, cancel, mux, tenant, deadline)
            }
        }
    }

    fn execute_train(
        &self,
        id: u64,
        p: &api::TrainParams,
        cancel: &CancelToken,
        mux: &EventMux,
        tenant: &str,
        deadline: Option<std::time::Instant>,
    ) -> Result<Outcome> {
        let cfg = p.training_config()?;
        let ckpt_path = self
            .state_dir
            .as_ref()
            .map(|dir| TrainCheckpoint::path_for(dir, id));
        let mut driver = match &ckpt_path {
            Some(path) if path.exists() => {
                // Newest-valid fallback: a truncated or bit-flipped
                // newest generation rolls back to the last good one
                // instead of failing the job.
                let ck = TrainCheckpoint::load_newest_valid(path)?;
                log::info(
                    "jobs",
                    format!(
                        "job {id}: resuming from checkpoint at iteration {}",
                        ck.history.len()
                    ),
                );
                TrainingDriver::with_resume(cfg, ck.store, ck.history)
                    .context("resuming from checkpoint")?
            }
            _ => TrainingDriver::new(cfg),
        };
        // Total-count semantics, same as `TrainingDriver::run_to`: a
        // resumed driver's `next_epoch` already counts checkpointed
        // iterations, so the job runs to `p.iters` *total* — never
        // `p.iters` more (pinned by the resume-equivalence test).
        while driver.next_epoch() < p.iters {
            if cancel.is_cancelled() {
                // Abort-shutdown keeps the checkpoint for restart
                // recovery; a client cancel means the job is dead.
                if !self.abort.load(Ordering::Acquire) {
                    if let Some(dir) = &self.state_dir {
                        TrainCheckpoint::remove(dir, id)?;
                    }
                }
                return Ok(Outcome::Cancelled);
            }
            if let Some(msg) = Self::deadline_hit(id, deadline) {
                // A deadline is the client bounding the job's lifetime:
                // terminal by policy, so the checkpoint goes too.
                if let Some(dir) = &self.state_dir {
                    TrainCheckpoint::remove(dir, id)?;
                }
                return Ok(Outcome::DeadlineExceeded(msg));
            }
            let epoch = driver.next_epoch();
            driver.run_iteration_observed(epoch, Some(Box::new(mux.clone())))?;
            self.set_progress(id, driver.history().len(), p.iters);
            if let Some(dir) = &self.state_dir {
                TrainCheckpoint {
                    job_id: id,
                    tenant: tenant.to_string(),
                    params: p.clone(),
                    history: driver.history().to_vec(),
                    store: driver.store().clone(),
                }
                .save_rotating(dir, self.keep_ckpts)?;
            }
            if p.throttle_ms > 0 && driver.next_epoch() < p.iters {
                std::thread::sleep(Duration::from_millis(p.throttle_ms));
            }
        }
        if let Some(dir) = &self.state_dir {
            TrainCheckpoint::remove(dir, id)?;
        }
        Ok(Outcome::Done(api::train_report(p, driver.history())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::api::{RolloutParams, TrainParams};

    fn rollout_spec() -> JobSpec {
        JobSpec::Rollout(RolloutParams {
            task: "moonlight".into(),
            scheduler: "seer".into(),
            sd: "grouped-cst".into(),
            seed: 42,
            bubble: 0.0,
            full: false,
        })
    }

    fn train_spec(iters: usize, throttle_ms: u64) -> JobSpec {
        JobSpec::Train(TrainParams {
            task: "moonlight".into(),
            scheduler: "seer".into(),
            sd: "grouped-cst".into(),
            iters,
            seed: 42,
            drift: 0.0,
            mode: crate::config::TrainingMode::Sync,
            cold: false,
            throttle_ms,
            trainer_faults: crate::sim::faults::FaultPlan::new(),
            full: false,
        })
    }

    /// Run `f` against a manager with `workers` live pool threads, then
    /// shut the pool down gracefully.
    fn with_pool<R>(
        manager: &JobManager,
        workers: usize,
        f: impl FnOnce() -> R,
    ) -> R {
        let runner = SweepRunner::new(workers);
        let worker = |i: usize| manager.worker_loop(i);
        std::thread::scope(|s| {
            runner.spawn_workers(s, &worker);
            let out = f();
            manager.request_shutdown(false);
            out
        })
    }

    #[test]
    fn submit_run_result_lifecycle() {
        let m = JobManager::new(QuotaConfig::default(), None).unwrap();
        let reply = with_pool(&m, 1, || {
            let id = m.submit("alice", rollout_spec(), JobControl::default()).unwrap();
            m.result_json(id)
        });
        assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));
        let result = reply.get("result").unwrap();
        assert!(result.get("completions").and_then(Json::as_u64).unwrap() > 0);
        // Events were tallied through the mux even with no subscriber.
        assert!(m.mux_of(1).unwrap().counts().finished > 0);
        assert_eq!(m.state_of(1), Some(JobState::Done));
    }

    #[test]
    fn quota_rejects_but_distinct_tenants_pass() {
        let m = JobManager::new(
            QuotaConfig {
                max_per_tenant: 1,
                max_jobs: 64,
            },
            None,
        )
        .unwrap();
        // No workers: jobs stay queued, holding their quota.
        m.submit("a", train_spec(1, 0), JobControl::default()).unwrap();
        let rejected = m.submit("a", train_spec(1, 0), JobControl::default()).unwrap_err();
        assert_eq!(
            rejected.get("code").and_then(Json::as_str),
            Some("quota")
        );
        m.submit("b", train_spec(1, 0), JobControl::default()).unwrap();
        // Cancelling frees the quota slot.
        m.cancel_json(1);
        assert!(m.submit("a", train_spec(1, 0), JobControl::default()).is_ok());
    }

    #[test]
    fn cancel_queued_job_never_runs() {
        let m = JobManager::new(QuotaConfig::default(), None).unwrap();
        let id = m.submit("a", rollout_spec(), JobControl::default()).unwrap();
        let reply = m.cancel_json(id);
        assert_eq!(
            reply.get("state").and_then(Json::as_str),
            Some("cancelled")
        );
        let result = with_pool(&m, 1, || m.result_json(id));
        assert_eq!(
            result.get("code").and_then(Json::as_str),
            Some("cancelled")
        );
    }

    #[test]
    fn unknown_ids_are_not_found() {
        let m = JobManager::new(QuotaConfig::default(), None).unwrap();
        for reply in [
            m.status_json(Some(99)),
            m.result_json(99),
            m.cancel_json(99),
        ] {
            assert_eq!(
                reply.get("code").and_then(Json::as_str),
                Some("not-found")
            );
        }
        assert!(m.mux_of(99).is_none());
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        let m = JobManager::new(QuotaConfig::default(), None).unwrap();
        m.request_shutdown(false);
        let e = m.submit("a", rollout_spec(), JobControl::default()).unwrap_err();
        assert_eq!(
            e.get("code").and_then(Json::as_str),
            Some("shutting-down")
        );
        assert!(m.drained());
    }

    #[test]
    fn resumed_job_and_resumed_cli_run_agree_on_total_iters() {
        // The PR-9 bugfix: both resume paths use *total-count*
        // semantics. A job checkpointed after 1 of 3 iterations must
        // finish with exactly 3 summaries — not 1 + 3 — and match a
        // CLI-style `run_to` resume from the same checkpoint bit for
        // bit.
        let JobSpec::Train(p) = train_spec(3, 0) else {
            unreachable!()
        };
        let mut seeded = TrainingDriver::new(p.training_config().unwrap());
        seeded.run_iteration(0).unwrap();
        let history = seeded.history().to_vec();
        let store = seeded.into_store();

        let dir = std::env::temp_dir()
            .join(format!("seer-jobs-resume-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        TrainCheckpoint {
            job_id: 7,
            tenant: "alice".into(),
            params: p.clone(),
            history: history.clone(),
            store: store.clone(),
        }
        .save(&dir)
        .unwrap();

        // Serve path: the manager recovers the checkpoint and runs the
        // job to completion.
        let m =
            JobManager::new(QuotaConfig::default(), Some(dir.clone())).unwrap();
        let reply = with_pool(&m, 1, || m.result_json(7));
        assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));
        let iters = reply
            .get("result")
            .and_then(|r| r.get("iterations"))
            .and_then(Json::as_arr)
            .unwrap();
        assert_eq!(iters.len(), 3, "serve resume must run to 3 total");

        // CLI path: `--load-ctx`-style resume through run_to.
        let mut cli = TrainingDriver::with_resume(
            p.training_config().unwrap(),
            store,
            history,
        )
        .unwrap();
        cli.run_to(p.iters).unwrap();
        assert_eq!(cli.history().len(), iters.len());
        let cli_json: Vec<String> =
            cli.history().iter().map(|s| s.to_json().to_string()).collect();
        let job_json: Vec<String> =
            iters.iter().map(|j| j.to_string()).collect();
        assert_eq!(cli_json, job_json);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn overload_sheds_newest_lowest_priority_queued_job() {
        let m = JobManager::new(
            QuotaConfig {
                max_per_tenant: 4,
                max_jobs: 2,
            },
            None,
        )
        .unwrap();
        // No workers: both jobs stay queued, filling the global cap.
        let low = |prio| JobControl {
            priority: prio,
            ..JobControl::default()
        };
        let j1 = m.submit("a", train_spec(1, 0), low(0)).unwrap();
        let j2 = m.submit("a", train_spec(1, 0), low(0)).unwrap();
        // Equal priority never sheds: the third submit is plain quota.
        let e = m.submit("b", train_spec(1, 0), low(0)).unwrap_err();
        assert_eq!(e.get("code").and_then(Json::as_str), Some("quota"));
        // Higher priority sheds the *newest* of the lowest-priority
        // queued jobs (j2, not j1) and is admitted in its place.
        let j4 = m.submit("b", train_spec(1, 0), low(5)).unwrap();
        assert_eq!(m.state_of(j2), Some(JobState::Shed));
        assert_eq!(m.state_of(j1), Some(JobState::Queued));
        assert_eq!(m.state_of(j4), Some(JobState::Queued));
        let r = m.result_json(j2);
        assert_eq!(r.get("code").and_then(Json::as_str), Some("shed"));
        let s = m.status_json(None);
        assert_eq!(s.get("shed").and_then(Json::as_u64), Some(1));
        // The shed job's mux is closed so subscribers drain immediately.
        assert!(m.mux_of(j2).unwrap().is_closed());
    }

    #[test]
    fn deadline_exceeded_is_terminal_and_drops_the_checkpoint() {
        let dir = std::env::temp_dir()
            .join(format!("seer-jobs-deadline-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let m =
            JobManager::new(QuotaConfig::default(), Some(dir.clone())).unwrap();
        let control = JobControl {
            deadline_secs: Some(0.05),
            ..JobControl::default()
        };
        // 3 iterations with a 100 ms throttle cannot fit in 50 ms: the
        // deadline check at the next iteration boundary must fire.
        let reply = with_pool(&m, 1, || {
            let id = m.submit("a", train_spec(3, 100), control).unwrap();
            m.result_json(id)
        });
        assert_eq!(
            reply.get("code").and_then(Json::as_str),
            Some("deadline-exceeded"),
            "{reply}"
        );
        assert_eq!(m.state_of(1), Some(JobState::DeadlineExceeded));
        assert!(
            !TrainCheckpoint::path_for(&dir, 1).exists(),
            "deadline-exceeded is terminal by policy; checkpoint must go"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retryable_failures_consume_the_attempt_budget_then_fail() {
        let dir = std::env::temp_dir()
            .join(format!("seer-jobs-retry-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // A directory squatting on the checkpoint tmp path makes every
        // checkpoint write fail with an I/O error — retryable, and
        // persistent across attempts.
        std::fs::create_dir_all(dir.join("train_1.ckpt.json.tmp")).unwrap();
        let m = JobManager::new(QuotaConfig::default(), Some(dir.clone()))
            .unwrap()
            .with_retry_policy(RetryPolicy {
                base_ms: 1,
                cap_ms: 2,
                seed: 1,
            });
        let control = JobControl {
            max_attempts: 3,
            ..JobControl::default()
        };
        let reply = with_pool(&m, 1, || {
            let id = m.submit("a", train_spec(2, 0), control).unwrap();
            m.result_json(id)
        });
        assert_eq!(
            reply.get("code").and_then(Json::as_str),
            Some("job-failed"),
            "{reply}"
        );
        let status = m.status_json(Some(1));
        assert_eq!(
            status.get("attempts").and_then(Json::as_u64),
            Some(3),
            "budget of 3 must be fully consumed: {status}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn non_retryable_failures_fail_fast_on_the_first_attempt() {
        let m = JobManager::new(QuotaConfig::default(), None).unwrap();
        // Built directly (parse-time validation would reject it): the
        // executor hits a deterministic config error.
        let JobSpec::Train(mut p) = train_spec(1, 0) else {
            unreachable!()
        };
        p.scheduler = "bogus".into();
        let control = JobControl {
            max_attempts: 5,
            ..JobControl::default()
        };
        let reply = with_pool(&m, 1, || {
            let id = m.submit("a", JobSpec::Train(p), control).unwrap();
            m.result_json(id)
        });
        assert_eq!(
            reply.get("code").and_then(Json::as_str),
            Some("job-failed")
        );
        let status = m.status_json(Some(1));
        assert_eq!(
            status.get("attempts").and_then(Json::as_u64),
            Some(1),
            "a deterministic failure must not burn the retry budget: {status}"
        );
    }

    #[test]
    fn status_summary_counts_states() {
        let m = JobManager::new(QuotaConfig::default(), None).unwrap();
        m.submit("a", train_spec(2, 0), JobControl::default()).unwrap();
        let s = m.status_json(None);
        assert_eq!(s.get("jobs").and_then(Json::as_u64), Some(1));
        assert_eq!(s.get("queued").and_then(Json::as_u64), Some(1));
        let per = m.status_json(Some(1));
        assert_eq!(per.get("kind").and_then(Json::as_str), Some("train"));
        let p = per.get("progress").unwrap();
        assert_eq!(p.get("iters_total").and_then(Json::as_u64), Some(2));
    }
}
