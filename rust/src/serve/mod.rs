//! The serve plane: a persistent rollout control plane over TCP.
//!
//! `seer serve` turns the binary into a daemon. Everything below the
//! wire is the *kernel* the rest of the crate already provides —
//! sessions, sweeps, the training driver — and this module adds only
//! the plane around it:
//!
//! * [`api`] — the line-delimited JSON protocol: requests, replies,
//!   and the typed [`api::JobSpec`] a `submit` carries.
//! * [`quota`] — admission control: per-tenant and global in-flight
//!   caps, typed rejections with machine-readable reasons (global-cap
//!   denials may trigger priority shedding; tenant-cap denials never
//!   do).
//! * [`jobs`] — the job table, queue, lifecycle state machine, and
//!   the executors that run each [`api::JobSpec`] kind on the
//!   [`crate::sweep::SweepRunner`] worker pool, with job-granular
//!   cancellation ([`crate::sweep::CancelToken`]), live event
//!   fan-out ([`crate::rollout::EventMux`]), per-job deadlines,
//!   bounded retry, and overload shedding ([`api::JobControl`]).
//! * [`retry`] — deterministic capped-exponential backoff with seeded
//!   jitter, plus retryable-vs-fatal error classification.
//! * [`checkpoint`] — crash-durable train-job state: atomic,
//!   checksummed, rotated per-iteration snapshots that a restarted
//!   daemon resumes byte-identically, falling back to the newest
//!   *valid* generation when the latest is torn.
//! * [`server`] — the TCP front end: accept loop, bounded line
//!   reader, verb dispatch, NDJSON `subscribe` streaming, graceful
//!   and abort shutdown.
//! * [`log`] — the one leveled stderr logger shared by the daemon
//!   and the CLI paths (stdout stays machine-readable).
//!
//! The protocol grammar and checkpoint format are documented in
//! ARCHITECTURE.md (serve-plane section); `tests/serve.rs` exercises
//! the whole plane over real sockets.

pub mod api;
pub mod checkpoint;
pub mod jobs;
pub mod log;
pub mod quota;
pub mod retry;
pub mod server;

pub use api::{
    JobControl, JobSpec, Request, RolloutParams, SweepParams, TrainParams,
};
pub use checkpoint::TrainCheckpoint;
pub use jobs::{JobManager, JobState};
pub use quota::{QuotaConfig, QuotaDenied};
pub use retry::RetryPolicy;
pub use server::{ServeConfig, Server};
