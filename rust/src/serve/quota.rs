//! Admission control: per-tenant and global in-flight job caps.
//!
//! The serve plane admits a job only while it can name the tenant a
//! truthful answer about capacity; everything past admission is the
//! queue's problem. A rejected submit carries a machine-readable code
//! (`"quota"`) plus a human reason, so clients can distinguish "try
//! later" from "your request is malformed".
//!
//! Quotas bound *in-flight* jobs (queued + running), not the run rate:
//! a tenant with quota 1 can keep exactly one job in the system at a
//! time, while worker-pool capacity — not the quota — decides whether
//! an admitted job runs immediately or waits in the queue.
//!
//! The denial is *typed* ([`QuotaDenied`]): a global-cap denial is
//! overload, which the manager may relieve by shedding a lower-priority
//! queued job; a tenant-cap denial is that tenant's own backlog and is
//! never grounds to shed someone else's work.

/// Why admission was refused. Carries the human reason; the variant
/// decides whether shedding may apply.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QuotaDenied {
    /// The whole daemon is at capacity — shedding a strictly
    /// lower-priority queued job may make room.
    GlobalCap(String),
    /// This tenant is at its own cap — only its jobs finishing (or
    /// being cancelled) makes room.
    TenantCap(String),
}

impl QuotaDenied {
    pub fn reason(&self) -> &str {
        match self {
            QuotaDenied::GlobalCap(r) | QuotaDenied::TenantCap(r) => r,
        }
    }
}

/// Admission limits for a [`crate::serve::jobs::JobManager`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuotaConfig {
    /// In-flight jobs allowed per tenant.
    pub max_per_tenant: usize,
    /// In-flight jobs allowed across all tenants.
    pub max_jobs: usize,
}

impl Default for QuotaConfig {
    fn default() -> Self {
        QuotaConfig {
            max_per_tenant: 4,
            max_jobs: 64,
        }
    }
}

impl QuotaConfig {
    /// Decide admission for a tenant currently holding
    /// `tenant_in_flight` jobs, with `total_in_flight` jobs in the
    /// system. `Err` is the typed rejection, its reason ready to send
    /// back.
    pub fn admit(
        &self,
        tenant: &str,
        tenant_in_flight: usize,
        total_in_flight: usize,
    ) -> Result<(), QuotaDenied> {
        if total_in_flight >= self.max_jobs {
            return Err(QuotaDenied::GlobalCap(format!(
                "global job cap reached ({} in flight, cap {})",
                total_in_flight, self.max_jobs
            )));
        }
        if tenant_in_flight >= self.max_per_tenant {
            return Err(QuotaDenied::TenantCap(format!(
                "tenant '{}' quota reached ({} in flight, quota {})",
                tenant, tenant_in_flight, self.max_per_tenant
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_under_both_caps() {
        let q = QuotaConfig {
            max_per_tenant: 2,
            max_jobs: 3,
        };
        assert!(q.admit("a", 0, 0).is_ok());
        assert!(q.admit("a", 1, 2).is_ok());
    }

    #[test]
    fn rejects_at_tenant_quota_with_reason() {
        let q = QuotaConfig {
            max_per_tenant: 1,
            max_jobs: 64,
        };
        let e = q.admit("alice", 1, 1).unwrap_err();
        assert!(matches!(e, QuotaDenied::TenantCap(_)), "{e:?}");
        assert!(e.reason().contains("alice"), "{e:?}");
        assert!(e.reason().contains("quota"), "{e:?}");
    }

    #[test]
    fn global_cap_wins_over_tenant_headroom() {
        let q = QuotaConfig {
            max_per_tenant: 4,
            max_jobs: 2,
        };
        let e = q.admit("bob", 0, 2).unwrap_err();
        assert!(matches!(e, QuotaDenied::GlobalCap(_)), "{e:?}");
        assert!(e.reason().contains("global"), "{e:?}");
    }
}
