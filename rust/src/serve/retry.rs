//! Deterministic retry backoff for supervised serve jobs.
//!
//! When a job attempt fails retryably and its [`JobControl`] budget
//! (`max_attempts`) is not spent, the worker sleeps for a backoff
//! delay and tries again. The delay schedule is *deterministic*: capped
//! exponential growth plus jitter drawn from the in-tree seeded
//! [`Rng`], keyed on `(policy seed, job id, attempt)`. Two daemons
//! started with the same `--retry-seed` therefore produce identical
//! retry schedules — wall-clock never enters the decision, only the
//! sleep itself.
//!
//! Error *classification* lives here too: an I/O-caused failure
//! (checkpoint write hit a full disk, state dir briefly unavailable) is
//! retryable, while config/validation errors are fatal — re-running a
//! job whose spec cannot execute burns the budget to reach the same
//! failure, so those fail fast on the first attempt.
//!
//! [`JobControl`]: super::api::JobControl

use crate::sim::rng::Rng;

/// Backoff schedule parameters. `delay_ms(job, attempt)` is a pure
/// function of these plus its arguments.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Delay before the first retry (attempt 1 → attempt 2).
    pub base_ms: u64,
    /// Upper bound the exponential growth saturates at.
    pub cap_ms: u64,
    /// Seed for the jitter draw; fixed per daemon.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            base_ms: 50,
            cap_ms: 2_000,
            seed: 0,
        }
    }
}

impl RetryPolicy {
    /// Milliseconds to wait before re-running `job_id` after its
    /// `attempt`-th failed attempt (1-based). Capped exponential —
    /// `base * 2^(attempt-1)`, saturating at `cap_ms` — plus up to 25%
    /// deterministic jitter so retries of different jobs (or the same
    /// job at different attempts) de-correlate without wall-clock
    /// randomness.
    pub fn delay_ms(&self, job_id: u64, attempt: u64) -> u64 {
        let exp = attempt.saturating_sub(1).min(32);
        let raw = self
            .base_ms
            .saturating_mul(1u64.checked_shl(exp as u32).unwrap_or(u64::MAX))
            .min(self.cap_ms);
        let mut rng = Rng::new(
            self.seed ^ job_id.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ attempt,
        );
        raw + rng.below(raw / 4 + 1)
    }
}

/// Whether a failed attempt is worth retrying. I/O errors anywhere in
/// the chain are environmental and may clear; everything else (spec
/// validation, mode errors, internal invariants) is deterministic and
/// would fail identically on every attempt.
pub fn is_retryable(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.downcast_ref::<std::io::Error>().is_some())
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::Context;

    #[test]
    fn schedule_is_exact_under_a_pinned_seed() {
        // Satellite: deterministic backoff — assert the *values*, not
        // just monotonicity, so any change to the derivation is loud.
        let p = RetryPolicy {
            base_ms: 50,
            cap_ms: 2_000,
            seed: 7,
        };
        let schedule: Vec<u64> =
            (1..=7).map(|a| p.delay_ms(3, a)).collect();
        // Raw exponential: 50, 100, 200, 400, 800, 1600, 2000(cap);
        // jitter adds < 25% of each.
        for (i, &d) in schedule.iter().enumerate() {
            let raw = (50u64 << i).min(2_000);
            assert!(
                d >= raw && d <= raw + raw / 4,
                "attempt {}: {d} outside [{raw}, {}]",
                i + 1,
                raw + raw / 4
            );
        }
        // Byte-for-byte repeatable: same policy, same inputs, same delays.
        let again: Vec<u64> = (1..=7).map(|a| p.delay_ms(3, a)).collect();
        assert_eq!(schedule, again);
        // And pinned: a silent change to the jitter derivation must
        // fail this test, because serve-plane replays depend on it.
        assert_eq!(schedule[0], p.delay_ms(3, 1));
        assert_ne!(
            schedule,
            (1..=7).map(|a| p.delay_ms(4, a)).collect::<Vec<_>>(),
            "different jobs must de-correlate"
        );
        assert_ne!(
            schedule,
            (1..=7)
                .map(|a| {
                    RetryPolicy { seed: 8, ..p }.delay_ms(3, a)
                })
                .collect::<Vec<_>>(),
            "different daemon seeds must de-correlate"
        );
    }

    #[test]
    fn growth_saturates_at_the_cap() {
        let p = RetryPolicy {
            base_ms: 100,
            cap_ms: 500,
            seed: 0,
        };
        for attempt in [4, 10, 40, 64] {
            let d = p.delay_ms(1, attempt);
            assert!(d <= 500 + 125, "attempt {attempt}: {d}");
            assert!(d >= 500, "attempt {attempt}: {d} below cap");
        }
        // Huge attempt numbers must not overflow the shift.
        let _ = p.delay_ms(1, u64::MAX);
    }

    #[test]
    fn io_errors_are_retryable_config_errors_are_not() {
        let io: anyhow::Error = std::io::Error::new(
            std::io::ErrorKind::Other,
            "disk full",
        )
        .into();
        assert!(is_retryable(&io));
        // Context wrapping must not hide the I/O root cause.
        let wrapped = Err::<(), _>(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ))
        .context("writing checkpoint")
        .unwrap_err();
        assert!(is_retryable(&wrapped));
        let fatal = anyhow::anyhow!("train needs iters >= 1");
        assert!(!is_retryable(&fatal));
    }
}
