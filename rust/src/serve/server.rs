//! The TCP front end of the serve plane.
//!
//! [`Server::bind`] opens a [`std::net::TcpListener`] and builds the
//! [`JobManager`] (recovering checkpoints); [`Server::run`] then hosts
//! everything on one [`std::thread::scope`]: the
//! [`crate::sweep::SweepRunner`] worker pool executing jobs, plus one
//! scoped thread per client connection. Each connection speaks the
//! line-delimited JSON protocol of [`super::api`]; `subscribe` switches
//! it to an NDJSON frame stream until the job's mux closes, then the
//! connection goes back to serving verbs. The accept loop is
//! non-blocking so it can notice shutdown: once a `shutdown` request
//! arrived *and* every admitted job is terminal, the listener stops,
//! the connection handlers see the same condition at their next read
//! timeout, and the scope joins — that is the whole graceful-exit
//! story, no detached threads anywhere.

use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::rollout::MuxFrame;
use crate::sweep::SweepRunner;
use crate::util::json::Json;

use super::api::{self, Request, MAX_LINE_BYTES};
use super::checkpoint::TrainCheckpoint;
use super::jobs::JobManager;
use super::log;
use super::quota::QuotaConfig;
use super::retry::RetryPolicy;

/// How often blocked reads and the accept loop re-check for shutdown.
const POLL_INTERVAL: Duration = Duration::from_millis(50);

/// How long a reply write may make zero progress before the connection
/// is declared dead. A subscriber that stops reading mid-NDJSON must
/// not pin its handler thread (and with it, daemon shutdown) forever.
const WRITE_STALL_BUDGET: Duration = Duration::from_secs(10);

/// Daemon configuration, filled in from CLI flags by `main`.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address; port 0 picks a free port (tests rely on this).
    pub addr: String,
    /// Worker-pool size; 0 means auto ([`SweepRunner::from_env`]).
    pub workers: usize,
    pub quota: QuotaConfig,
    /// Where train jobs checkpoint; `None` disables checkpointing.
    pub state_dir: Option<PathBuf>,
    /// Checkpoint generations kept per train job (`--keep-ckpts`).
    pub keep_ckpts: usize,
    /// Retry backoff schedule for supervised jobs (`--retry-seed`).
    pub retry: RetryPolicy,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            workers: 0,
            quota: QuotaConfig::default(),
            state_dir: None,
            keep_ckpts: TrainCheckpoint::DEFAULT_KEEP,
            retry: RetryPolicy::default(),
        }
    }
}

/// A bound-but-not-yet-running daemon. Splitting bind from run lets
/// tests bind port 0, read [`Server::local_addr`], and only then hand
/// the server to a thread.
pub struct Server {
    listener: TcpListener,
    manager: Arc<JobManager>,
    workers: usize,
}

impl Server {
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        listener
            .set_nonblocking(true)
            .context("setting listener non-blocking")?;
        let workers = if cfg.workers == 0 {
            SweepRunner::from_env().threads()
        } else {
            cfg.workers
        };
        let manager = Arc::new(
            JobManager::new(cfg.quota, cfg.state_dir)?
                .with_retry_policy(cfg.retry)
                .with_keep_ckpts(cfg.keep_ckpts),
        );
        Ok(Server {
            listener,
            manager,
            workers,
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading local addr")
    }

    /// Serve until a client-requested shutdown completes.
    pub fn run(self) -> Result<()> {
        let Server {
            listener,
            manager,
            workers,
        } = self;
        log::info(
            "server",
            format!(
                "listening on {} ({workers} workers)",
                listener.local_addr().context("reading local addr")?
            ),
        );
        let pool = SweepRunner::new(workers);
        let worker = |i: usize| manager.worker_loop(i);
        std::thread::scope(|s| {
            pool.spawn_workers(s, &worker);
            loop {
                match listener.accept() {
                    Ok((stream, peer)) => {
                        let mgr = Arc::clone(&manager);
                        s.spawn(move || {
                            if let Err(e) = handle_conn(stream, &mgr) {
                                log::debug(
                                    "server",
                                    format!("connection {peer}: {e:#}"),
                                );
                            }
                        });
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        if manager.drained() {
                            break;
                        }
                        std::thread::sleep(POLL_INTERVAL);
                    }
                    Err(e) => {
                        log::warn("server", format!("accept failed: {e}"));
                        std::thread::sleep(POLL_INTERVAL);
                    }
                }
            }
        });
        log::info("server", "shut down cleanly");
        Ok(())
    }
}

/// What one bounded line read produced.
enum LineIn {
    Line(String),
    /// The client exceeded [`MAX_LINE_BYTES`] without a newline.
    TooLong,
    /// Peer closed its write side.
    Eof,
    /// The daemon finished shutting down while the client was idle.
    ServerClosing,
}

/// A newline-framed reader over a timeout-polling stream. Plain
/// `BufReader::read_line` would buffer without bound and block without
/// a shutdown check; this does neither.
struct LineReader {
    stream: TcpStream,
    pending: Vec<u8>,
}

impl LineReader {
    fn next_line(&mut self, manager: &JobManager) -> std::io::Result<LineIn> {
        loop {
            if let Some(pos) = self.pending.iter().position(|&b| b == b'\n') {
                if pos > MAX_LINE_BYTES {
                    return Ok(LineIn::TooLong);
                }
                let rest = self.pending.split_off(pos + 1);
                let mut line = std::mem::replace(&mut self.pending, rest);
                line.pop();
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                return Ok(LineIn::Line(
                    String::from_utf8_lossy(&line).into_owned(),
                ));
            }
            if self.pending.len() > MAX_LINE_BYTES {
                return Ok(LineIn::TooLong);
            }
            let mut chunk = [0u8; 4096];
            match self.stream.read(&mut chunk) {
                Ok(0) => return Ok(LineIn::Eof),
                Ok(n) => self.pending.extend_from_slice(&chunk[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut
                    ) =>
                {
                    if manager.drained() {
                        return Ok(LineIn::ServerClosing);
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Discard input up to and including the next newline (or EOF), in
    /// constant memory. Called after an over-long line so the reply can
    /// be sent and the socket closed cleanly — closing with unread data
    /// still queued would reset the connection under the reply.
    fn discard_line(&mut self, manager: &JobManager) -> std::io::Result<()> {
        if self.pending.iter().any(|&b| b == b'\n') {
            return Ok(());
        }
        self.pending.clear();
        let mut chunk = [0u8; 4096];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => return Ok(()),
                Ok(n) if chunk[..n].contains(&b'\n') => return Ok(()),
                Ok(_) => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut
                    ) =>
                {
                    if manager.drained() {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }
}

/// Write one reply line, polling on the stream's short write timeout.
/// Errors when the peer is gone (broken pipe / reset) or stops reading
/// long enough to exhaust [`WRITE_STALL_BUDGET`] with zero progress —
/// a plain `write_all` on a full send buffer would block the handler
/// thread unboundedly, wedging daemon shutdown behind one dead client.
fn send(w: &mut TcpStream, reply: &Json) -> std::io::Result<()> {
    let mut line = reply.to_string();
    line.push('\n');
    let buf = line.as_bytes();
    let mut written = 0usize;
    let mut last_progress = std::time::Instant::now();
    while written < buf.len() {
        match w.write(&buf[written..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::WriteZero,
                    "peer closed mid-write",
                ))
            }
            Ok(n) => {
                written += n;
                last_progress = std::time::Instant::now();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut
                ) =>
            {
                if last_progress.elapsed() >= WRITE_STALL_BUDGET {
                    return Err(std::io::Error::new(
                        ErrorKind::TimedOut,
                        "client stopped reading",
                    ));
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// One NDJSON stream frame. Event frames are the event's own
/// [`crate::rollout::RolloutEvent::to_json`] plus a `"type":"event"`
/// tag — strip the tag and you have exactly what a direct in-process
/// observer saw, which is what the stream-equivalence test checks.
fn frame_json(job: u64, frame: &MuxFrame, manager: &JobManager) -> Json {
    let mut o = BTreeMap::new();
    match frame {
        MuxFrame::Event(ev) => {
            let mut j = ev.to_json();
            if let Json::Obj(fields) = &mut j {
                fields.insert(
                    "type".to_string(),
                    Json::Str("event".to_string()),
                );
            }
            return j;
        }
        MuxFrame::Telemetry { counts, now } => {
            o.insert("type".to_string(), Json::Str("telemetry".to_string()));
            o.insert("counts".to_string(), counts.to_json());
            o.insert("t_us".to_string(), Json::Num(now.as_micros() as f64));
        }
        MuxFrame::Truncated => {
            o.insert("type".to_string(), Json::Str("truncated".to_string()));
        }
        MuxFrame::Closed => {
            o.insert("type".to_string(), Json::Str("end".to_string()));
            o.insert("job".to_string(), Json::Num(job as f64));
            let state = manager
                .state_of(job)
                .map(|s| s.name())
                .unwrap_or("unknown");
            o.insert("state".to_string(), Json::Str(state.to_string()));
        }
    }
    Json::Obj(o)
}

/// Serve one connection until EOF, an oversized line, or daemon exit.
fn handle_conn(stream: TcpStream, manager: &JobManager) -> Result<()> {
    stream
        .set_read_timeout(Some(POLL_INTERVAL))
        .context("setting read timeout")?;
    stream
        .set_write_timeout(Some(POLL_INTERVAL))
        .context("setting write timeout")?;
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = LineReader {
        stream,
        pending: Vec::new(),
    };
    loop {
        let line = match reader.next_line(manager)? {
            LineIn::Line(l) => l,
            LineIn::TooLong => {
                reader.discard_line(manager)?;
                send(
                    &mut writer,
                    &api::err_reply(
                        "bad-request",
                        "request line exceeds 1 MiB",
                    ),
                )?;
                return Ok(());
            }
            LineIn::Eof | LineIn::ServerClosing => return Ok(()),
        };
        if line.trim().is_empty() {
            continue;
        }
        let req = match Request::parse(&line) {
            Ok(r) => r,
            Err(e) => {
                send(
                    &mut writer,
                    &api::err_reply("bad-request", &format!("{e:#}")),
                )?;
                continue;
            }
        };
        match req {
            Request::Submit {
                tenant,
                spec,
                control,
            } => {
                let reply = match manager.submit(&tenant, spec, control) {
                    Ok(id) => {
                        api::ok_reply(vec![("job", Json::Num(id as f64))])
                    }
                    Err(rejection) => rejection,
                };
                send(&mut writer, &reply)?;
            }
            Request::Status { job } => {
                send(&mut writer, &manager.status_json(job))?;
            }
            Request::Result { job } => {
                send(&mut writer, &manager.result_json(job))?;
            }
            Request::Cancel { job } => {
                send(&mut writer, &manager.cancel_json(job))?;
            }
            Request::Subscribe { job } => {
                let Some(mux) = manager.mux_of(job) else {
                    send(
                        &mut writer,
                        &api::err_reply("not-found", &format!("no job {job}")),
                    )?;
                    continue;
                };
                let rx = mux.subscribe();
                send(
                    &mut writer,
                    &api::ok_reply(vec![
                        ("job", Json::Num(job as f64)),
                        ("streaming", Json::Bool(true)),
                    ]),
                )?;
                for frame in rx {
                    if let Err(e) =
                        send(&mut writer, &frame_json(job, &frame, manager))
                    {
                        // The subscriber went away (or stopped reading)
                        // mid-stream. Dropping `rx` is the idempotent
                        // unsubscribe — the mux prunes the dead channel
                        // at its next emission — and the job itself
                        // never waited on this connection, so teardown
                        // here is purely local.
                        log::debug(
                            "server",
                            format!(
                                "subscriber of job {job} dropped mid-stream: \
                                 {e}"
                            ),
                        );
                        return Ok(());
                    }
                    if frame == MuxFrame::Closed {
                        break;
                    }
                }
            }
            Request::Shutdown { abort } => {
                send(
                    &mut writer,
                    &api::ok_reply(vec![
                        ("shutting_down", Json::Bool(true)),
                        (
                            "mode",
                            Json::Str(
                                if abort { "abort" } else { "graceful" }
                                    .to_string(),
                            ),
                        ),
                    ]),
                )?;
                manager.request_shutdown(abort);
            }
        }
    }
}
