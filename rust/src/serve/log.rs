//! One leveled logging helper for the daemon *and* the CLI paths.
//!
//! Everything human-readable goes to **stderr**, tagged
//! `[seer][LEVEL][component]`; stdout is reserved for machine output
//! (JSON reports, NDJSON streams), which is what lets the CI smoke
//! tests assert a quiet stdout. The threshold comes from the `SEER_LOG`
//! environment variable (`error`, `warn`, `info`, `debug`; default
//! `info`) and is re-read on every call — log volume here is human
//! scale, and re-reading keeps tests free to flip it.

use std::fmt::Display;

/// Log severity, ordered from most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
}

impl Level {
    pub fn name(&self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    pub fn from_name(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// The active threshold: `SEER_LOG`, else `info`. Unparsable values
/// fall back to `info` rather than erroring — logging must never be the
/// thing that kills a daemon.
pub fn threshold() -> Level {
    std::env::var("SEER_LOG")
        .ok()
        .and_then(|s| Level::from_name(&s))
        .unwrap_or(Level::Info)
}

/// Emit one line to stderr if `level` passes the threshold.
pub fn emit(level: Level, component: &str, msg: impl Display) {
    if level <= threshold() {
        eprintln!("[seer][{}][{component}] {msg}", level.name());
    }
}

pub fn error(component: &str, msg: impl Display) {
    emit(Level::Error, component, msg);
}

pub fn warn(component: &str, msg: impl Display) {
    emit(Level::Warn, component, msg);
}

pub fn info(component: &str, msg: impl Display) {
    emit(Level::Info, component, msg);
}

pub fn debug(component: &str, msg: impl Display) {
    emit(Level::Debug, component, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_round_trip() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::from_name(l.name()), Some(l));
        }
        assert_eq!(Level::from_name("WARNING"), Some(Level::Warn));
        assert_eq!(Level::from_name("verbose"), None);
    }
}
