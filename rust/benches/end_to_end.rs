//! End-to-end benches: one full cluster rollout per paper table/figure
//! configuration (test scale), reporting sim-seconds simulated per
//! wall-second — the whole-simulator hot path that the §Perf pass
//! optimizes. Run the `experiment` CLI for the full-scale numbers.

use std::time::Instant;

use seer::config::{SystemConfig, TaskPreset, ALL_PRESETS};
use seer::engine::cluster::run_rollout;
use seer::scheduler::{
    ContextMode, Scheduler, SeerScheduler, StreamRlOracle, VerlScheduler,
};
use seer::spec::simmodel::SdStrategy;

fn time_one(
    label: &str,
    preset: TaskPreset,
    sched: Box<dyn Scheduler>,
    sd: SdStrategy,
) {
    let cfg = preset.workload_for_test();
    let sys = SystemConfig {
        chunk_size: (cfg.avg_gen_len / 4).clamp(32, 2048),
        ..Default::default()
    };
    let t0 = Instant::now();
    let out = run_rollout(&cfg, &sys, sched, sd, 42);
    let wall = t0.elapsed().as_secs_f64();
    let sim = out.metrics.makespan.as_secs_f64();
    println!(
        "bench e2e_{label}: wall {wall:.3}s sim {sim:.1}s speedup {:.0}x \
         ({} reqs, {} tokens)",
        sim / wall.max(1e-9),
        out.metrics.completions.len(),
        out.metrics.tokens_generated
    );
}

fn main() {
    // Table 4 ladder on each preset (the per-table end-to-end benches).
    for preset in ALL_PRESETS {
        let name = preset.name().replace('-', "_");
        time_one(
            &format!("{name}_verl"),
            preset,
            Box::new(VerlScheduler::new()),
            SdStrategy::None,
        );
        time_one(
            &format!("{name}_streamrl"),
            preset,
            Box::new(StreamRlOracle::new()),
            SdStrategy::None,
        );
        time_one(
            &format!("{name}_seer_nosd"),
            preset,
            Box::new(SeerScheduler::new(ContextMode::Learned)),
            SdStrategy::None,
        );
        time_one(
            &format!("{name}_seer_full"),
            preset,
            Box::new(SeerScheduler::new(ContextMode::Learned)),
            SdStrategy::GroupedCst,
        );
    }
}
