//! End-to-end benches: one full cluster rollout per paper table/figure
//! configuration (test scale), reporting sim-seconds simulated per
//! wall-second — the whole-simulator hot path that the §Perf pass
//! optimizes. Run the `experiment` CLI for the full-scale numbers.
//!
//! Rollouts are constructed through the unified `RolloutSession` builder
//! with registry policy names, like every other front door.

use seer::config::{SystemConfig, TaskPreset, ALL_PRESETS};
use seer::rollout::RolloutSession;

fn time_one(label: &str, preset: TaskPreset, scheduler: &str, sd: &str) {
    let cfg = preset.workload_for_test();
    let sys = SystemConfig {
        chunk_size: (cfg.avg_gen_len / 4).clamp(32, 2048),
        ..Default::default()
    };
    let report = RolloutSession::builder()
        .workload(cfg)
        .system(sys)
        .scheduler(scheduler)
        .sd(sd)
        .seed(42)
        .run()
        .expect("rollout session failed");
    let wall = report.wall_secs;
    let sim = report.metrics.makespan.as_secs_f64();
    println!(
        "bench e2e_{label}: wall {wall:.3}s sim {sim:.1}s speedup {:.0}x \
         ({} reqs, {} tokens)",
        sim / wall.max(1e-9),
        report.metrics.completions.len(),
        report.metrics.tokens_generated
    );
}

fn main() {
    // Table 4 ladder on each preset (the per-table end-to-end benches).
    for preset in ALL_PRESETS {
        let name = preset.name().replace('-', "_");
        time_one(&format!("{name}_verl"), preset, "verl", "none");
        time_one(&format!("{name}_streamrl"), preset, "streamrl", "none");
        time_one(&format!("{name}_seer_nosd"), preset, "seer", "none");
        time_one(&format!("{name}_seer_full"), preset, "seer", "grouped-cst");
        time_one(
            &format!("{name}_rollpacker"),
            preset,
            "rollpacker",
            "grouped-cst",
        );
    }
}
