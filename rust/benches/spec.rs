//! Speculative-decoding benchmarks: CST append/match/speculate (the DGDS
//! critical path), multi-path drafting, and the MBA allocation loop.

use seer::config::TaskPreset;
use seer::engine::costmodel::CostModel;
use seer::sim::clock::SimTime;
use seer::spec::cst::Cst;
use seer::spec::mba::{mba_allocate, MbaInputs};
use seer::spec::multipath::speculate_multipath;
use seer::util::bench::{bench, bench_val};
use seer::workload::tokens::{GroupTokenGen, TokenGenConfig};

fn main() {
    let gen = GroupTokenGen::new(TokenGenConfig::default(), 3);
    let streams: Vec<Vec<u32>> =
        (0..8).map(|i| gen.response(i, 4000, 10 + i as u64)).collect();

    // Append throughput (tokens/sec through the suffix automaton).
    {
        let mut req = 0u64;
        bench("cst_append_4000_tokens", || {
            let mut cst = Cst::new();
            cst.append(req, 0, &streams[(req % 8) as usize]);
            req += 1;
        });
    }

    // Query path: pattern match + linear draft on a populated group CST.
    let mut cst = Cst::new();
    for (i, s) in streams.iter().enumerate() {
        cst.append(i as u64, 0, s);
    }
    let target = gen.response(9, 2000, 99);
    let mut pos = 100usize;
    bench_val("cst_speculate_gamma8", || {
        let pattern = &target[pos - 24..pos];
        pos = 100 + (pos + 7) % 1800;
        cst.speculate(pattern, 8, 24, 2)
    });

    let mut pos2 = 100usize;
    bench_val("cst_multipath_k4_gamma8", || {
        let pattern = &target[pos2 - 24..pos2];
        pos2 = 100 + (pos2 + 7) % 1800;
        speculate_multipath(&cst, pattern, 8, 24, 2, 4, 0.01)
    });

    // MBA allocation (runs once per replan interval per instance).
    let cost = CostModel::new(&TaskPreset::Moonlight.workload().hw);
    let inputs = MbaInputs {
        batch_high: 8,
        batch_low: 120,
        beta: vec![0.6, 0.55, 0.5, 0.44, 0.38, 0.3, 0.22, 0.15],
        gamma_max: 8,
        lambda: 2.0,
        alpha: 0.5,
        kv_tokens: 800_000,
        draft_cost_per_gamma: SimTime::from_micros(2),
    };
    bench_val("mba_allocate_128_batch", || {
        mba_allocate(&cost, &inputs)
    });
}
