//! KVCache benchmarks: the paged allocator's grow/release cycle under a
//! realistic batch, and global-pool store/fetch/spill churn.

use seer::config::TaskPreset;
use seer::kvcache::{GlobalKvPool, PagedAllocator};
use seer::sim::Rng;
use seer::util::bench::{bench, bench_val};
use seer::workload::RequestId;

fn main() {
    // Paged allocator: 256-request batch growing one step.
    let mut alloc = PagedAllocator::new(1_250_000, 64);
    for i in 0..256u32 {
        alloc.grow(RequestId(i), 2048);
    }
    let mut step = 0u32;
    bench("paged_grow_256_requests_one_step", || {
        for i in 0..256u32 {
            alloc.grow_upto(RequestId(i), 2);
        }
        step += 1;
        if step % 500 == 0 {
            // Reset before capacity exhausts.
            for i in 0..256u32 {
                alloc.release(RequestId(i));
                alloc.grow(RequestId(i), 2048);
            }
        }
    });

    bench_val("paged_utilization_query", || alloc.utilization());

    // Global pool churn at Mooncake scale.
    let hw = TaskPreset::Qwen2Vl72b.workload().hw;
    let mut pool = GlobalKvPool::new(&hw, 16);
    let mut rng = Rng::new(5);
    let mut id = 0u32;
    bench("pool_store_fetch_cycle", || {
        let bytes = 1_000_000 + rng.below(500_000_000);
        pool.store(RequestId(id % 4096), bytes);
        if id % 3 == 0 {
            let victim = RequestId(rng.below(id.max(1) as u64) as u32 % 4096);
            let _ = pool.fetch(victim);
        }
        id += 1;
    });
    println!(
        "pool state after churn: {:?} spills",
        pool.stats().spills
    );
}
