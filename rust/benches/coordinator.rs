//! Coordinator hot-path benchmarks: the Algorithm-2 scheduling decision
//! loop, context-manager updates, and the request buffer — the L3 paths
//! the §Perf pass optimizes.

use seer::config::{SystemConfig, TaskPreset};
use seer::coordinator::RequestBuffer;
use seer::rollout::PolicyRegistry;
use seer::scheduler::{InstanceView, SchedCtx, Scheduler};
use seer::sim::clock::SimTime;
use seer::util::bench::bench_val;
use seer::workload::{generate_iteration, InstanceId};

fn views(cfg: &seer::config::WorkloadConfig) -> Vec<InstanceView> {
    (0..cfg.n_instances as u32)
        .map(|i| InstanceView {
            id: InstanceId(i),
            free_kv_tokens: cfg.hw.kv_capacity_tokens / 2,
            capacity_tokens: cfg.hw.kv_capacity_tokens,
            running: 4,
            max_batch: cfg.hw.max_batch,
        })
        .collect()
}

fn main() {
    // Full paper-scale waiting set: 3200 requests, 32 instances.
    let cfg = TaskPreset::Moonlight.workload();
    let sys = SystemConfig::default();
    let w = generate_iteration(&cfg, 1);
    let buffer = RequestBuffer::from_groups(&w.groups);
    let instances = views(&cfg);

    // Policies come from the registry, like every other front door.
    // Assignments go into a reused scratch vec, as in the driver; the
    // incremental schedulers return examined candidates at pass end, so
    // repeated passes over the static buffer stay representative.
    let registry = PolicyRegistry::builtin();
    let mut out = Vec::new();
    let mut seer = registry.scheduler("seer").unwrap();
    seer.init(&w.groups, &cfg, &sys);
    bench_val("seer_schedule_3200_waiting_32_inst", || {
        let ctx = SchedCtx {
            now: SimTime::ZERO,
            instances: &instances,
            buffer: &buffer,
        };
        out.clear();
        seer.schedule(&ctx, &mut out);
        out.len()
    });

    let mut verl = registry.scheduler("verl").unwrap();
    verl.init(&w.groups, &cfg, &sys);
    bench_val("verl_schedule_3200_waiting_32_inst", || {
        let ctx = SchedCtx {
            now: SimTime::ZERO,
            instances: &instances,
            buffer: &buffer,
        };
        out.clear();
        verl.schedule(&ctx, &mut out);
        out.len()
    });

    // Lifecycle accounting: the O(1) counters the event loop's done()
    // check reads every event, vs the scan they replaced.
    bench_val("buffer_done_check_counter", || {
        (buffer.all_finished(), buffer.n_finished())
    });
    bench_val("buffer_done_check_scan_reference", || {
        buffer.n_finished_scan()
    });

    // Context-manager update path.
    let mut cm = seer::coordinator::ContextManager::new(cfg.max_gen_len);
    cm.init_groups(&w.groups);
    let mut i = 0u32;
    bench_val("context_manager_on_finished", || {
        let g = seer::workload::GroupId(i % cfg.n_groups() as u32);
        cm.on_finished(g, 1000 + i);
        i += 1;
        cm.estimate(g)
    });

    // Buffer lifecycle churn.
    let mut buf = RequestBuffer::from_groups(&w.groups);
    let ids: Vec<_> = buf.waiting().take(1024).collect();
    bench_val("buffer_schedule_unschedule_1024", || {
        for &id in &ids {
            buf.mark_scheduled(id);
        }
        for &id in &ids {
            buf.mark_waiting(id);
        }
    });
}
